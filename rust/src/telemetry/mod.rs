//! Always-on, zero-steady-state-alloc observability: phase spans, counters
//! and gauges recorded into per-thread pre-sized ring buffers, exported as
//! a Chrome trace-event JSON (`paragan train --trace out.json`), an
//! aggregate [`TelemetryReport`] (per-phase Streaming stats + p50/p95/p99
//! via `util::stats`, rendered through `util::table`), and phase-breakdown
//! fields in `BENCH_dist.json` / `BENCH_step_alloc.json`.
//!
//! **Hot-path contract.**  After a thread's first span (which registers its
//! lane — one `Arc` + one pre-sized slot array, warmup territory),
//! recording allocates NOTHING and takes no lock: a span is two
//! `Instant` reads, one thread-local access, one slot write and one
//! `Release` store ([`Ring::record`] is single-writer wait-free; readers
//! never block the writer).  `tests/step_alloc.rs` pins the zero-alloc
//! claim with the counting allocator and recording enabled; the ring's
//! publish protocol is loom-model-checked in `tests/loom_models.rs`.
//!
//! **Boundary discipline (PR-9 decision).**  Instrumentation lives ONLY at
//! the boundary layers — `runtime/step.rs`, `coordinator/*`, `dist/*`,
//! `pipeline/*` — never inside the pure compute modules
//! (kernel/ref_conv/workspace/plan).  `cargo xtask lint`'s
//! `telemetry-purity` rule rejects any `telemetry::` reference in those
//! files; state the pure modules already own (the kernel's SIMD degrade
//! count, the workspace's overflow-fallback count) is MIRRORED into the
//! report at read time instead.
//!
//! **On/off.**  Enabled by default; `PARAGAN_TELEMETRY=off` (or
//! [`set_enabled`]`(Some(false))` — a tri-state like the workspace arena's)
//! reduces every record site to one relaxed atomic load, which is what
//! `benches/bench_telemetry.rs` measures the ≤ 2% overhead gate against.
//! Ring capacity is [`DEFAULT_RING_CAP`] events per lane, overridable via
//! `PARAGAN_TELEMETRY_CAP`; a full ring DROPS new events (counted) rather
//! than wrapping, so published slots are immutable and concurrent readers
//! are safe by construction.

use std::cell::{Cell, OnceCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};
use crate::util::stats::{Sample, Streaming};
use crate::util::sync::Mutex;
use crate::util::table::Table;

// The ring itself is built on the `util::sync` shim so the loom lane can
// model-check the publish protocol with the exact production code.
use crate::util::sync::atomic as shim_atomic;
use crate::util::sync::UnsafeCell;

// ---------------------------------------------------------------------------
// Phases, counters, gauges
// ---------------------------------------------------------------------------

/// The span taxonomy.  One phase per boundary the step pipeline crosses;
/// trainers never invent ad-hoc names, so traces and reports are
/// comparable across modes and PRs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Phase {
    /// Waiting on the data pipeline for a real batch (`next_batch`).
    DataWait = 0,
    /// Inference-only artifact execution (generate / fid_features).
    Generate = 1,
    /// Discriminator forward+backward (fused or grads-only).
    DGrads = 2,
    /// Generator forward+backward (fused or grads-only).
    GGrads = 3,
    /// All-reduce / exchange wait (sync dist mode).
    Exchange = 4,
    /// Optimizer update from externally reduced gradients.
    Apply = 5,
    /// Publishing a parameter snapshot for the peer side.
    SnapshotPublish = 6,
    /// Recycled-shell turnaround: refill + hand-off of a reused batch.
    Recycle = 7,
    /// Waiting on the fake-batch exchange (async D side `pop_batch`).
    FakeWait = 8,
    /// One gradient bucket's exchange round on the overlap lane's
    /// communicator thread (`dist::overlap`) — runs concurrently with the
    /// producing replica's backward, so its total is comm BUSY time;
    /// [`Phase::Exchange`] on the worker lane keeps meaning EXPOSED wait.
    BucketExchange = 9,
}

pub const PHASE_COUNT: usize = 10;

impl Phase {
    pub const ALL: [Phase; PHASE_COUNT] = [
        Phase::DataWait,
        Phase::Generate,
        Phase::DGrads,
        Phase::GGrads,
        Phase::Exchange,
        Phase::Apply,
        Phase::SnapshotPublish,
        Phase::Recycle,
        Phase::FakeWait,
        Phase::BucketExchange,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::DataWait => "data_wait",
            Phase::Generate => "generate",
            Phase::DGrads => "d_grads",
            Phase::GGrads => "g_grads",
            Phase::Exchange => "exchange_wait",
            Phase::Apply => "apply",
            Phase::SnapshotPublish => "snapshot_publish",
            Phase::Recycle => "recycle",
            Phase::FakeWait => "fake_wait",
            Phase::BucketExchange => "bucket_exchange",
        }
    }

    fn from_u8(v: u8) -> Option<Phase> {
        Phase::ALL.get(v as usize).copied()
    }
}

/// Map a step artifact key to its span phase — the ONE place the
/// `d_step_*` / `g_step_*` / `generate*` naming convention is interpreted,
/// so `runtime/step.rs` stays free of per-trainer knowledge.
pub fn phase_for_step_key(key: &str) -> Phase {
    if key.starts_with("d_step") {
        Phase::DGrads
    } else if key.starts_with("g_step") {
        Phase::GGrads
    } else {
        Phase::Generate
    }
}

/// Monotonic event counters (wait-free `fetch_add`).  The report also
/// mirrors two counts owned by the pure modules (never instrumented
/// directly — see the module docs): the kernel's SIMD lane degradations
/// and the workspace's overflow-fallback takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// Parameter-server pushes admitted within the staleness bound.
    StaleAdmit = 0,
    /// Parameter-server pushes dropped as too stale.
    StaleDrop = 1,
    /// Recycled-shell reuse: a free-list pop served the request.
    FreeListHit = 2,
    /// Free list empty: a fresh allocation was taken instead.
    FreeListMiss = 3,
    /// Consumed batches handed back through a recycle channel.
    BatchRecycled = 4,
}

pub const COUNTER_COUNT: usize = 5;

impl Counter {
    pub const ALL: [Counter; COUNTER_COUNT] = [
        Counter::StaleAdmit,
        Counter::StaleDrop,
        Counter::FreeListHit,
        Counter::FreeListMiss,
        Counter::BatchRecycled,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::StaleAdmit => "staleness_admits",
            Counter::StaleDrop => "staleness_drops",
            Counter::FreeListHit => "free_list_hits",
            Counter::FreeListMiss => "free_list_fresh_allocs",
            Counter::BatchRecycled => "batches_recycled",
        }
    }
}

/// Last-value gauges (with a high-water mark) for queue depths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Gauge {
    /// Prefetcher ready-queue depth observed at `next_batch`.
    QueueDepth = 0,
    /// Fake-batch exchange (`ImgBuff`) depth observed at the hand-off.
    FakeBuffDepth = 1,
    /// Percent (0–100) of the last step's exchange busy time the overlap
    /// lane hid under backward compute: `100 * (busy - exposed) / busy`,
    /// set once per `dist::overlap` step from the communicator's bucket
    /// busy time vs. the worker's exposed `exchange_wait`.
    OverlapHiddenPct = 2,
}

pub const GAUGE_COUNT: usize = 3;

impl Gauge {
    pub const ALL: [Gauge; GAUGE_COUNT] =
        [Gauge::QueueDepth, Gauge::FakeBuffDepth, Gauge::OverlapHiddenPct];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "pipeline_queue_depth",
            Gauge::FakeBuffDepth => "fake_buff_depth",
            Gauge::OverlapHiddenPct => "overlap_hidden_pct",
        }
    }
}

// ---------------------------------------------------------------------------
// The ring: single-writer pre-sized event log
// ---------------------------------------------------------------------------

/// One recorded span.  24 bytes, `Copy`, so slots publish by value.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Event {
    /// Nanoseconds since the process-wide trace epoch.
    pub start_ns: u64,
    /// Span duration in nanoseconds.
    pub dur_ns: u64,
    /// `Phase` discriminant.
    pub phase: u8,
    /// Nesting depth at span open (0 = top level).
    pub depth: u8,
}

/// Pre-sized single-writer event log with lock-free publication.
///
/// Protocol (loom-checked in `tests/loom_models.rs`):
/// * ONE owning thread calls [`Ring::record`]: write slot `head`, then
///   store `head + 1` with `Release`.  A full ring drops (counted).
/// * Any thread may read: `Acquire`-load `head`, then read only slots
///   below it — published slots are never rewritten (no wrap), so reads
///   race nothing.
/// * [`Ring::reset`] is quiescent-only (callers hold no concurrent
///   writer — benches reset between runs after joining workers).
#[derive(Debug)]
pub struct Ring {
    slots: Box<[UnsafeCell<Event>]>,
    head: shim_atomic::AtomicUsize,
    dropped: shim_atomic::AtomicU64,
}

// SAFETY: `slots[i]` is written only by the single owning writer thread and
// only while `i >= head`; the `Release` store of `head + 1` in `record`
// publishes the write, and readers touch a slot only after an `Acquire`
// load of `head` shows it published — after which it is immutable (the
// ring never wraps).  `reset` is documented quiescent-only.
unsafe impl Sync for Ring {}
// SAFETY: moving a `Ring` between threads transfers plain owned data; the
// slot cells carry no thread affinity of their own (the single-writer
// discipline above is what guards access, not the owning thread identity).
unsafe impl Send for Ring {}

impl Ring {
    pub fn new(cap: usize) -> Ring {
        let slots: Vec<UnsafeCell<Event>> =
            (0..cap.max(1)).map(|_| UnsafeCell::new(Event::default())).collect();
        Ring {
            slots: slots.into_boxed_slice(),
            head: shim_atomic::AtomicUsize::new(0),
            dropped: shim_atomic::AtomicU64::new(0),
        }
    }

    /// Append one event.  Single-writer: only the lane's owning thread may
    /// call this.  Wait-free, allocation-free; a full ring drops.
    pub fn record(&self, ev: Event) {
        // Relaxed is enough for the writer's own read of head — it is the
        // only thread that ever stores it.
        let h = self.head.load(Ordering::Relaxed);
        if h >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.slots[h].with_mut(|p| {
            // SAFETY: single-writer protocol — slot `h` is unpublished
            // (`h >= head`), so no reader touches it, and no other writer
            // exists.  See the `Sync` impl note above.
            unsafe { *p = ev }
        });
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy every published event into `out` (append).  Safe concurrently
    /// with the writer: only slots below the `Acquire`-loaded head are
    /// read, and those are immutable.
    pub fn snapshot(&self, out: &mut Vec<Event>) {
        let h = self.head.load(Ordering::Acquire);
        for slot in self.slots.iter().take(h) {
            out.push(slot.with(|p| {
                // SAFETY: `slot` is below the published head, hence
                // initialized and never written again.
                unsafe { *p }
            }));
        }
    }

    /// Published event count.
    pub fn len(&self) -> usize {
        self.head.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events lost to a full ring.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Capacity in events.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Forget all published events.  QUIESCENT-ONLY: the caller must
    /// guarantee no concurrent `record`/`snapshot` (benches call this
    /// between runs, after every worker has joined).
    pub fn reset(&self) {
        self.head.store(0, Ordering::SeqCst);
        self.dropped.store(0, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Global state: enable switch, epoch, counters, lane registry
// ---------------------------------------------------------------------------

/// Default per-lane ring capacity (events).  ~16k spans ≈ 3k+ steps of the
/// densest lane; 16 bytes each keeps a lane under 256 KiB.
pub const DEFAULT_RING_CAP: usize = 1 << 14;

/// Tri-state like the workspace arena's: 0 = follow `PARAGAN_TELEMETRY`,
/// 1 = forced off, 2 = forced on.  Plain std atomic (const-initializable;
/// this switch is config, not modeled concurrency).
static MODE: AtomicUsize = AtomicUsize::new(0);

#[allow(clippy::declare_interior_mutable_const)]
const COUNTER_ZERO: AtomicU64 = AtomicU64::new(0);
static COUNTERS: [AtomicU64; COUNTER_COUNT] = [COUNTER_ZERO; COUNTER_COUNT];
static GAUGE_LAST: [AtomicU64; GAUGE_COUNT] = [COUNTER_ZERO; GAUGE_COUNT];
static GAUGE_MAX: [AtomicU64; GAUGE_COUNT] = [COUNTER_ZERO; GAUGE_COUNT];

struct Lane {
    /// Chrome trace `tid` (registration ordinal — unique per lane).
    tid: usize,
    /// Display name: `replica{k}` when the thread is replica-bound at
    /// registration, else `main`.
    name: String,
    ring: Ring,
}

static REGISTRY: OnceLock<Mutex<Vec<Arc<Lane>>>> = OnceLock::new();

thread_local! {
    static TL_LANE: OnceCell<Arc<Lane>> = const { OnceCell::new() };
    static TL_DEPTH: Cell<u32> = const { Cell::new(0) };
}

fn env_default_on() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !std::env::var("PARAGAN_TELEMETRY")
            .map(|v| matches!(v.trim(), "off" | "0" | "false"))
            .unwrap_or(false)
    })
}

fn env_ring_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("PARAGAN_TELEMETRY_CAP")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(DEFAULT_RING_CAP)
    })
}

/// Is recording on right now?  One relaxed load — this is the entire cost
/// of every record site when telemetry is disabled.
#[inline]
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => env_default_on(),
    }
}

/// Set the process-wide recording mode (`None` restores the
/// `PARAGAN_TELEMETRY` env default).  Same tri-state shape as
/// `workspace::set_arena_mode`, and used the same way by the A/B overhead
/// bench.
pub fn set_enabled(on: Option<bool>) {
    let v = match on {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    MODE.store(v, Ordering::SeqCst);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

#[inline]
fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn registry() -> &'static Mutex<Vec<Arc<Lane>>> {
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register the calling thread's lane (cold: once per thread, allocates
/// the ring — warmup territory by the zero-steady-state contract).
fn register_lane() -> Arc<Lane> {
    let name = match crate::runtime::workspace::bound_replica() {
        Some(k) => format!("replica{k}"),
        None => "main".to_string(),
    };
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let lane = Arc::new(Lane { tid: reg.len(), name, ring: Ring::new(env_ring_cap()) });
    reg.push(lane.clone());
    lane
}

#[inline]
fn with_lane<R>(f: impl FnOnce(&Lane) -> R) -> R {
    TL_LANE.with(|cell| f(cell.get_or_init(register_lane)))
}

// ---------------------------------------------------------------------------
// Recording API
// ---------------------------------------------------------------------------

/// An open phase span; records on drop.  Inert (two field writes, no
/// timestamp) when telemetry is disabled.
#[must_use = "a span records when dropped — bind it to a guard variable"]
pub struct SpanGuard {
    start_ns: u64,
    phase: Phase,
    depth: u32,
    armed: bool,
}

/// Open a span for `phase` on the calling thread.  Nested spans record
/// their depth, and the Chrome export nests them by time containment.
#[inline]
pub fn span(phase: Phase) -> SpanGuard {
    if !enabled() {
        return SpanGuard { start_ns: 0, phase, depth: 0, armed: false };
    }
    let depth = TL_DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard { start_ns: now_ns(), phase, depth, armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur_ns = now_ns().saturating_sub(self.start_ns);
        TL_DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        with_lane(|lane| {
            lane.ring.record(Event {
                start_ns: self.start_ns,
                dur_ns,
                phase: self.phase as u8,
                depth: self.depth.min(u8::MAX as u32) as u8,
            });
        });
    }
}

/// Bump a counter by `n`.  Wait-free; no-op when disabled.
#[inline]
pub fn count(c: Counter, n: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Set a gauge's current value (also tracks the high-water mark).
#[inline]
pub fn gauge(g: Gauge, v: u64) {
    if enabled() {
        GAUGE_LAST[g as usize].store(v, Ordering::Relaxed);
        GAUGE_MAX[g as usize].fetch_max(v, Ordering::Relaxed);
    }
}

/// Total events published across every lane (tests assert recording
/// actually happened inside measured sections).
pub fn events_recorded() -> u64 {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|l| l.ring.len() as u64).sum()
}

/// Current value of a counter.
pub fn counter_value(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Forget all recorded events, counters and gauges.  QUIESCENT-ONLY (see
/// [`Ring::reset`]); lanes of finished threads stay registered but empty.
pub fn reset() {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    for lane in reg.iter() {
        lane.ring.reset();
    }
    for c in &COUNTERS {
        c.store(0, Ordering::SeqCst);
    }
    for g in &GAUGE_LAST {
        g.store(0, Ordering::SeqCst);
    }
    for g in &GAUGE_MAX {
        g.store(0, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Aggregation: TelemetryReport
// ---------------------------------------------------------------------------

/// Aggregate stats for one phase across all lanes.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: Phase,
    pub count: u64,
    pub total_secs: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

/// One gauge's last value and high-water mark.
#[derive(Debug, Clone, Copy)]
pub struct GaugeStat {
    pub gauge: Gauge,
    pub last: u64,
    pub max: u64,
}

/// The per-run aggregate summary: phase quantiles, counters (including the
/// mirrored pure-module counts), gauges, and recording health.
#[derive(Debug, Clone)]
pub struct TelemetryReport {
    /// Phases with at least one span, in `Phase::ALL` order.
    pub phases: Vec<PhaseStat>,
    /// `(name, value)` — the `Counter` set plus mirrored counts
    /// (`simd_lane_degradations` from the kernel, `workspace_overflow_takes`
    /// from the workspace arena).
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<GaugeStat>,
    /// Lanes that recorded at least one event.
    pub active_lanes: usize,
    pub events: u64,
    /// Events lost to full rings.
    pub dropped: u64,
}

/// Build the aggregate report from everything recorded so far.
pub fn report() -> TelemetryReport {
    let mut events: Vec<Event> = Vec::new();
    let mut active_lanes = 0usize;
    let mut dropped = 0u64;
    {
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        for lane in reg.iter() {
            let before = events.len();
            lane.ring.snapshot(&mut events);
            if events.len() > before {
                active_lanes += 1;
            }
            dropped += lane.ring.dropped();
        }
    }

    let mut samples: Vec<Sample> = (0..PHASE_COUNT).map(|_| Sample::new()).collect();
    let mut totals: Vec<Streaming> = (0..PHASE_COUNT).map(|_| Streaming::new()).collect();
    for ev in &events {
        let i = ev.phase as usize;
        if i < PHASE_COUNT {
            samples[i].push(ev.dur_ns as f64 / 1e3); // µs
            totals[i].push(ev.dur_ns as f64 / 1e9); // s
        }
    }
    let mut phases = Vec::new();
    for phase in Phase::ALL {
        let i = phase as usize;
        if samples[i].is_empty() {
            continue;
        }
        let s = &mut samples[i];
        phases.push(PhaseStat {
            phase,
            count: s.len() as u64,
            total_secs: totals[i].mean() * totals[i].count() as f64,
            mean_us: s.mean(),
            p50_us: s.quantile(0.50),
            p95_us: s.quantile(0.95),
            p99_us: s.quantile(0.99),
            max_us: s.quantile(1.0),
        });
    }

    let mut counters: Vec<(&'static str, u64)> =
        Counter::ALL.iter().map(|&c| (c.name(), counter_value(c))).collect();
    // Mirrored pure-module counts (the modules themselves are never
    // instrumented — PR-9 boundary discipline).
    counters.push((
        "simd_lane_degradations",
        crate::runtime::kernel::simd_degradations(),
    ));
    counters.push((
        "workspace_overflow_takes",
        crate::runtime::workspace::total_overflow_takes(),
    ));

    let gauges = Gauge::ALL
        .iter()
        .map(|&g| GaugeStat {
            gauge: g,
            last: GAUGE_LAST[g as usize].load(Ordering::Relaxed),
            max: GAUGE_MAX[g as usize].load(Ordering::Relaxed),
        })
        .collect();

    TelemetryReport {
        phases,
        counters,
        gauges,
        active_lanes,
        events: events.len() as u64,
        dropped,
    }
}

impl TelemetryReport {
    /// Render the report as `util::table` markdown (phases + counters).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "telemetry — phase spans",
            &["phase", "count", "total s", "mean µs", "p50 µs", "p95 µs", "p99 µs", "max µs"],
        );
        for p in &self.phases {
            t.row(vec![
                p.phase.name().to_string(),
                p.count.to_string(),
                format!("{:.3}", p.total_secs),
                format!("{:.1}", p.mean_us),
                format!("{:.1}", p.p50_us),
                format!("{:.1}", p.p95_us),
                format!("{:.1}", p.p99_us),
                format!("{:.1}", p.max_us),
            ]);
        }
        let mut c = Table::new("telemetry — counters & gauges", &["name", "value", "max"]);
        for (name, v) in &self.counters {
            c.row(vec![name.to_string(), v.to_string(), String::new()]);
        }
        for g in &self.gauges {
            c.row(vec![g.gauge.name().to_string(), g.last.to_string(), g.max.to_string()]);
        }
        c.row(vec![
            "trace_events".to_string(),
            self.events.to_string(),
            format!("dropped {}", self.dropped),
        ]);
        format!("{}\n{}", t.render(), c.render())
    }

    /// The phase-breakdown object benches embed per run:
    /// `{ "<phase>": {count, total_secs, mean_us, p50_us, p95_us, p99_us}, ... }`.
    pub fn phases_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for p in &self.phases {
            m.insert(
                p.phase.name().to_string(),
                json::obj(vec![
                    ("count", json::num(p.count as f64)),
                    ("total_secs", json::num(p.total_secs)),
                    ("mean_us", json::num(p.mean_us)),
                    ("p50_us", json::num(p.p50_us)),
                    ("p95_us", json::num(p.p95_us)),
                    ("p99_us", json::num(p.p99_us)),
                ]),
            );
        }
        Json::Obj(m)
    }

    /// Full report as JSON (phases + counters + gauges + health).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, v) in &self.counters {
            counters.insert(name.to_string(), json::num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for g in &self.gauges {
            gauges.insert(
                g.gauge.name().to_string(),
                json::obj(vec![
                    ("last", json::num(g.last as f64)),
                    ("max", json::num(g.max as f64)),
                ]),
            );
        }
        json::obj(vec![
            ("phases", self.phases_json()),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("active_lanes", json::num(self.active_lanes as f64)),
            ("events", json::num(self.events as f64)),
            ("dropped_events", json::num(self.dropped as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

/// Everything recorded so far as a Chrome trace-event JSON value
/// (object form: `{"traceEvents": [...], "counters": {...}}`) — load it at
/// `chrome://tracing` or <https://ui.perfetto.dev>.  One lane (`tid`) per
/// recording thread, complete (`"ph":"X"`) events whose nesting follows
/// time containment, thread-name metadata per lane, and final counter
/// values both as `"ph":"C"` samples and a top-level `counters` object.
pub fn chrome_trace_json() -> Json {
    let mut trace_events: Vec<Json> = Vec::new();
    let mut end_ts_us = 0.0f64;
    let mut scratch: Vec<Event> = Vec::new();
    {
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        for lane in reg.iter() {
            scratch.clear();
            lane.ring.snapshot(&mut scratch);
            if scratch.is_empty() {
                continue;
            }
            trace_events.push(json::obj(vec![
                ("name", json::s("thread_name")),
                ("ph", json::s("M")),
                ("pid", json::num(1.0)),
                ("tid", json::num(lane.tid as f64)),
                ("args", json::obj(vec![("name", json::s(&lane.name))])),
            ]));
            // Spans record on drop, so lane order is END order (an inner
            // span lands before its enclosing parent); trace viewers sort
            // by ts themselves, so events go out in record order.
            for ev in &scratch {
                let ts = ev.start_ns as f64 / 1e3;
                let dur = ev.dur_ns as f64 / 1e3;
                end_ts_us = end_ts_us.max(ts + dur);
                let name = Phase::from_u8(ev.phase).map(Phase::name).unwrap_or("unknown");
                trace_events.push(json::obj(vec![
                    ("name", json::s(name)),
                    ("ph", json::s("X")),
                    ("ts", json::num(ts)),
                    ("dur", json::num(dur)),
                    ("pid", json::num(1.0)),
                    ("tid", json::num(lane.tid as f64)),
                    ("args", json::obj(vec![("depth", json::num(ev.depth as f64))])),
                ]));
            }
        }
    }
    let rep = report();
    let mut counters = BTreeMap::new();
    for (name, v) in &rep.counters {
        counters.insert(name.to_string(), json::num(*v as f64));
        trace_events.push(json::obj(vec![
            ("name", json::s(name)),
            ("ph", json::s("C")),
            ("ts", json::num(end_ts_us)),
            ("pid", json::num(1.0)),
            ("tid", json::num(0.0)),
            ("args", json::obj(vec![("value", json::num(*v as f64))])),
        ]));
    }
    json::obj(vec![
        ("traceEvents", json::arr(trace_events)),
        ("displayTimeUnit", json::s("ms")),
        ("counters", Json::Obj(counters)),
    ])
}

/// Write the Chrome trace to `path` (the `paragan train --trace FILE`
/// export).
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    let mut out = String::new();
    json::write_json(&chrome_trace_json(), &mut out);
    out.push('\n');
    std::fs::write(path, out).with_context(|| format!("writing chrome trace to {path:?}"))
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    // Tests that flip the global MODE run under this lock so they cannot
    // interleave their tri-state with each other.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn ev(start_ns: u64, dur_ns: u64, phase: Phase, depth: u8) -> Event {
        Event { start_ns, dur_ns, phase: phase as u8, depth }
    }

    #[test]
    fn ring_records_in_order_and_snapshots() {
        let r = Ring::new(8);
        r.record(ev(10, 5, Phase::DataWait, 0));
        r.record(ev(20, 7, Phase::DGrads, 1));
        let mut out = Vec::new();
        r.snapshot(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].start_ns, 10);
        assert_eq!(out[1].phase, Phase::DGrads as u8);
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn full_ring_drops_instead_of_wrapping() {
        let r = Ring::new(2);
        r.record(ev(1, 1, Phase::Apply, 0));
        r.record(ev(2, 1, Phase::Apply, 0));
        r.record(ev(3, 1, Phase::Apply, 0));
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 1);
        let mut out = Vec::new();
        r.snapshot(&mut out);
        // The published prefix is intact — the overflow never rewrote it.
        assert_eq!(out[0].start_ns, 1);
        assert_eq!(out[1].start_ns, 2);
        r.reset();
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn step_key_phase_mapping() {
        assert_eq!(phase_for_step_key("d_step_adam_fp32"), Phase::DGrads);
        assert_eq!(phase_for_step_key("g_step_adabelief_fp32"), Phase::GGrads);
        assert_eq!(phase_for_step_key("generate_fp32"), Phase::Generate);
        assert_eq!(phase_for_step_key("fid_features"), Phase::Generate);
    }

    #[test]
    fn phase_roundtrips_through_u8() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_u8(p as u8), Some(p));
        }
        assert_eq!(Phase::from_u8(PHASE_COUNT as u8), None);
    }

    #[test]
    fn disabled_span_is_inert() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(Some(false));
        let before = events_recorded();
        {
            let _s = span(Phase::Apply);
        }
        assert_eq!(events_recorded(), before, "disabled span must not record");
        set_enabled(None);
    }

    #[test]
    fn spans_nest_and_aggregate_into_report() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(Some(true));
        // Fresh thread -> fresh lane, so counts below are exact.
        let handle = std::thread::spawn(|| {
            for _ in 0..4 {
                let _outer = span(Phase::DGrads);
                let _inner = span(Phase::Generate);
            }
        });
        handle.join().unwrap();
        set_enabled(None);
        let rep = report();
        let d = rep.phases.iter().find(|p| p.phase == Phase::DGrads).expect("d_grads present");
        assert!(d.count >= 4);
        let g = rep.phases.iter().find(|p| p.phase == Phase::Generate).expect("generate present");
        assert!(g.count >= 4);
        assert!(d.p50_us <= d.p99_us + 1e-9);
    }

    #[test]
    fn chrome_trace_roundtrips_and_nests() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(Some(true));
        let handle = std::thread::spawn(|| {
            let _outer = span(Phase::GGrads);
            std::thread::sleep(std::time::Duration::from_millis(1));
            let _inner = span(Phase::SnapshotPublish);
        });
        handle.join().unwrap();
        set_enabled(None);
        let mut text = String::new();
        json::write_json(&chrome_trace_json(), &mut text);
        let root = json::parse(&text).expect("trace JSON parses");
        let evs = root.get("traceEvents").as_arr().expect("traceEvents array");
        assert!(!evs.is_empty());
        // Every X event is well-formed; nested spans are time-contained in
        // their enclosing span on the same tid.
        for e in evs {
            match e.get("ph").as_str() {
                Some("X") => {
                    assert!(e.get("ts").as_f64().is_some());
                    assert!(e.get("dur").as_f64().unwrap_or(-1.0) >= 0.0);
                    assert!(e.get("tid").as_f64().is_some());
                }
                Some("M") | Some("C") => {}
                other => panic!("unexpected event kind {other:?}"),
            }
        }
        assert!(root.get("counters").as_obj().is_some());
    }

    #[test]
    fn counters_and_gauges_accumulate() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(Some(true));
        let before = counter_value(Counter::FreeListHit);
        count(Counter::FreeListHit, 3);
        assert_eq!(counter_value(Counter::FreeListHit), before + 3);
        gauge(Gauge::QueueDepth, 5);
        gauge(Gauge::QueueDepth, 2);
        let rep = report();
        let g = rep
            .gauges
            .iter()
            .find(|g| g.gauge == Gauge::QueueDepth)
            .expect("queue depth gauge");
        assert_eq!(g.last, 2);
        assert!(g.max >= 5);
        set_enabled(None);
    }

    #[test]
    fn report_json_has_schema_fields() {
        let rep = report();
        let j = rep.to_json();
        assert!(j.get("phases").as_obj().is_some());
        assert!(j.get("counters").as_obj().is_some());
        assert!(j.get("counters").get("staleness_admits").as_f64().is_some());
        assert!(j.get("counters").get("simd_lane_degradations").as_f64().is_some());
        assert!(j.get("counters").get("workspace_overflow_takes").as_f64().is_some());
        assert!(j.get("gauges").get("pipeline_queue_depth").as_obj().is_some());
    }
}
