//! Property-testing mini-framework (proptest is not in the offline vendor
//! set — see DESIGN.md §1).
//!
//! Deterministic generators driven by `util::rng`, a `forall` runner, and
//! greedy shrinking for integer/vec cases.  Coordinator invariants (routing,
//! batching, buffer ordering, tuner bounds, layout plans) are tested with
//! this throughout the crate.
//!
//! ```ignore
//! forall(gens::vec(gens::u64_below(100), 0..50), |xs| {
//!     let mut s = xs.clone(); s.sort(); s.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use crate::util::rng::Rng;

pub const DEFAULT_CASES: usize = 256;

/// Reference artifacts for tests/examples: a loadable `manifest.json` +
/// `.ref.json` descriptors with no Python, no `make artifacts`, and no
/// native XLA — see `runtime::refgen`.
///
/// Published under ONE stable temp path (content is deterministic, so any
/// complete copy is as good as any other).  Writers stage into a
/// pid-suffixed dir and atomically rename it into place; losing the
/// publish race just means adopting the winner's copy, so parallel
/// `cargo test` binaries neither race nor accumulate per-pid directories.
///
/// The `-v2` suffix versions the artifact SCHEMA (v2 added the conv
/// backbones + `arch` descriptors).  Staleness is not left to the suffix
/// alone: a cached copy is only adopted after its manifest actually lists
/// every model the current `refgen::default_models()` exports, so a
/// forgotten bump regenerates instead of silently serving old artifacts.
pub fn ref_artifact_dir() -> std::path::PathBuf {
    use std::sync::OnceLock;
    static DIR: OnceLock<std::path::PathBuf> = OnceLock::new();

    fn cache_is_current(dir: &std::path::Path) -> bool {
        crate::runtime::Manifest::load(dir)
            .map(|m| {
                crate::runtime::refgen::default_models()
                    .iter()
                    .all(|spec| m.models.contains_key(spec.name))
            })
            .unwrap_or(false)
    }

    DIR.get_or_init(|| {
        let base = std::env::temp_dir().join("paragan-ref-artifacts-v2");
        if cache_is_current(&base) {
            return base;
        }
        let staging = std::env::temp_dir()
            .join(format!("paragan-ref-artifacts-v2.{}", std::process::id()));
        crate::runtime::refgen::write_ref_artifacts(&staging)
            .expect("writing reference artifacts");
        // Evict a stale occupant (missing models) before publishing.
        if base.exists() && !cache_is_current(&base) {
            let _ = std::fs::remove_dir_all(&base);
        }
        match std::fs::rename(&staging, &base) {
            Ok(()) => base,
            // Rename fails when another process already published `base`:
            // adopt theirs if complete and current, otherwise keep serving
            // our staging copy.
            Err(_) if cache_is_current(&base) => {
                let _ = std::fs::remove_dir_all(&staging);
                base
            }
            Err(_) => staging,
        }
    })
    .clone()
}

/// Pick real AOT artifacts when this build can execute them (pjrt feature
/// compiled in AND `make artifacts` has run), else the generated reference
/// set — then resolve `model` IN the chosen set.  Since the reference set
/// exports real `dcgan32`/`sngan32` conv artifacts, the requested model is
/// what actually runs; an unknown model is a hard error listing what IS
/// available, never a silent substitution.
pub fn artifacts_for(model: &str) -> anyhow::Result<(std::path::PathBuf, String)> {
    let real = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let dir = if cfg!(feature = "pjrt") && real.join("manifest.json").exists() {
        real
    } else {
        ref_artifact_dir()
    };
    let manifest = crate::runtime::Manifest::load(&dir)?;
    anyhow::ensure!(
        manifest.models.contains_key(model),
        "model '{model}' is not in the artifact set at {dir:?} (available: {:?}); \
         refusing to substitute a different backbone",
        manifest.models.keys().collect::<Vec<_>>()
    );
    Ok((dir, model.to_string()))
}

/// A generator produces a value from entropy and knows how to shrink it.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values, in decreasing priority. Default: none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` on `DEFAULT_CASES` generated cases; panic with the smallest
/// found counterexample.
pub fn forall<G: Gen>(gen: G, prop: impl Fn(&G::Value) -> bool) {
    forall_cases(gen, DEFAULT_CASES, prop)
}

pub fn forall_cases<G: Gen>(gen: G, cases: usize, prop: impl Fn(&G::Value) -> bool) {
    // Fixed seed: reproducible CI. Vary per case index.
    for case in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let min = shrink_to_min(&gen, v, &prop);
            panic!("property failed (case {case}); minimal counterexample: {min:?}");
        }
    }
}

fn shrink_to_min<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // Greedy: repeatedly take the first shrink candidate that still fails.
    'outer: for _ in 0..10_000 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

pub mod gens {
    use super::Gen;
    use crate::util::rng::Rng;

    pub struct U64Below(pub u64);
    impl Gen for U64Below {
        type Value = u64;
        fn generate(&self, rng: &mut Rng) -> u64 {
            rng.below(self.0.max(1))
        }
        fn shrink(&self, v: &u64) -> Vec<u64> {
            let mut out = Vec::new();
            if *v > 0 {
                out.push(0);
                out.push(v / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        }
    }
    pub fn u64_below(n: u64) -> U64Below {
        U64Below(n)
    }

    pub struct UsizeIn(pub std::ops::Range<usize>);
    impl Gen for UsizeIn {
        type Value = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            self.0.start + rng.usize_below((self.0.end - self.0.start).max(1))
        }
        fn shrink(&self, v: &usize) -> Vec<usize> {
            let lo = self.0.start;
            let mut out = Vec::new();
            if *v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        }
    }
    pub fn usize_in(r: std::ops::Range<usize>) -> UsizeIn {
        UsizeIn(r)
    }

    pub struct F64In(pub f64, pub f64);
    impl Gen for F64In {
        type Value = f64;
        fn generate(&self, rng: &mut Rng) -> f64 {
            rng.range_f64(self.0, self.1)
        }
        fn shrink(&self, v: &f64) -> Vec<f64> {
            if *v != self.0 {
                vec![self.0, self.0 + (v - self.0) / 2.0]
            } else {
                vec![]
            }
        }
    }
    pub fn f64_in(lo: f64, hi: f64) -> F64In {
        F64In(lo, hi)
    }

    pub struct VecOf<G>(pub G, pub std::ops::Range<usize>);
    impl<G: Gen> Gen for VecOf<G> {
        type Value = Vec<G::Value>;
        fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
            let n = self.1.start + rng.usize_below((self.1.end - self.1.start).max(1));
            (0..n).map(|_| self.0.generate(rng)).collect()
        }
        fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
            let mut out = Vec::new();
            if v.len() > self.1.start {
                // Halve, drop-front, drop-back — never below the min length.
                let half = (v.len() / 2).max(self.1.start);
                out.push(v[..half].to_vec());
                out.push(v[1..].to_vec());
                out.push(v[..v.len() - 1].to_vec());
            }
            // Shrink one element.
            for (i, x) in v.iter().enumerate().take(8) {
                for cand in self.0.shrink(x) {
                    let mut copy = v.clone();
                    copy[i] = cand;
                    out.push(copy);
                }
            }
            out
        }
    }
    pub fn vec<G: Gen>(g: G, len: std::ops::Range<usize>) -> VecOf<G> {
        VecOf(g, len)
    }

    pub struct Pair<A, B>(pub A, pub B);
    impl<A: Gen, B: Gen> Gen for Pair<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> =
                self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
            out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
            out
        }
    }
    pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> Pair<A, B> {
        Pair(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::gens::*;
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(vec(u64_below(100), 0..20), |xs| xs.iter().all(|&x| x < 100));
    }

    #[test]
    fn finds_and_shrinks_counterexample() {
        let res = std::panic::catch_unwind(|| {
            forall(u64_below(1000), |&x| x < 500);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land exactly on the boundary 500.
        assert!(msg.contains("500"), "{msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let g = vec(u64_below(10), 0..30);
        let v: Vec<u64> = (0..10).collect();
        let shrunk = g.shrink(&v);
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        let g = u64_below(1_000_000);
        for case in 0..5 {
            let mut rng =
                Rng::new(0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
            first.push(g.generate(&mut rng));
        }
        let mut second = Vec::new();
        for case in 0..5 {
            let mut rng =
                Rng::new(0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
            second.push(g.generate(&mut rng));
        }
        assert_eq!(first, second);
    }
}
