//! Evaluation metrics (paper §3.1.3): FID-proxy, IS-proxy, mode coverage,
//! loss tracking.  The feature extractor is the `fid_features` AOT artifact;
//! this module owns the statistics and reporting.

pub mod fid;
pub mod tracker;

pub use fid::{frechet_distance, inception_score_proxy, mode_coverage, FeatureStats, Mat};
pub use tracker::{sparkline, Series, SeriesPoint};
