//! Loss/metric tracking over a training run: per-step series, EMA smoothing,
//! collapse detection, CSV/markdown export for the Fig. 6 / Fig. 13 curves.

use crate::util::stats::Ema;

#[derive(Debug, Clone)]
pub struct SeriesPoint {
    pub step: u64,
    pub value: f64,
}

#[derive(Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<SeriesPoint>,
    ema: Ema,
    pub smoothed: Vec<SeriesPoint>,
}

/// Growth chunk for series built without a capacity hint: `push` reserves
/// whole chunks instead of letting two `Vec`s double independently mid-step.
const SERIES_CHUNK: usize = 1024;

impl Series {
    pub fn new(name: &str, ema_alpha: f64) -> Self {
        Series::with_capacity(name, ema_alpha, 0)
    }

    /// Pre-size both point vectors for a planned run length (trainers pass
    /// `cfg.steps`), so a long training loop never reallocs its loss series.
    pub fn with_capacity(name: &str, ema_alpha: f64, capacity: usize) -> Self {
        Series {
            name: name.to_string(),
            points: Vec::with_capacity(capacity),
            ema: Ema::new(ema_alpha),
            smoothed: Vec::with_capacity(capacity),
        }
    }

    pub fn push(&mut self, step: u64, value: f64) {
        // Chunked growth for un-hinted series: one reserve per SERIES_CHUNK
        // steps rather than a realloc whenever either Vec happens to fill.
        if self.points.len() == self.points.capacity() {
            self.points.reserve(SERIES_CHUNK);
        }
        if self.smoothed.len() == self.smoothed.capacity() {
            self.smoothed.reserve(SERIES_CHUNK);
        }
        self.points.push(SeriesPoint { step, value });
        let s = self.ema.push(value);
        self.smoothed.push(SeriesPoint { step, value: s });
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.value)
    }

    pub fn last_smoothed(&self) -> Option<f64> {
        self.smoothed.last().map(|p| p.value)
    }

    /// Mean of the final `frac` of the series (end-of-training level).
    pub fn tail_mean(&self, frac: f64) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let start = ((1.0 - frac.clamp(0.0, 1.0)) * self.points.len() as f64) as usize;
        let tail = &self.points[start.min(self.points.len() - 1)..];
        tail.iter().map(|p| p.value).sum::<f64>() / tail.len() as f64
    }

    /// Std-dev of the final `frac` — the paper's "flatter loss curve"
    /// stability criterion (Fig. 6).
    pub fn tail_std(&self, frac: f64) -> f64 {
        if self.points.len() < 2 {
            return 0.0;
        }
        let start = ((1.0 - frac.clamp(0.0, 1.0)) * self.points.len() as f64) as usize;
        let tail = &self.points[start.min(self.points.len() - 2)..];
        let m = tail.iter().map(|p| p.value).sum::<f64>() / tail.len() as f64;
        (tail.iter().map(|p| (p.value - m) * (p.value - m)).sum::<f64>()
            / (tail.len() - 1).max(1) as f64)
            .sqrt()
    }

    /// Detect a late-training blow-up: tail level much worse than the best
    /// smoothed level (the Fig. 6 "Adam collapses after 100K steps" shape).
    pub fn collapsed(&self, factor: f64) -> bool {
        let best =
            self.smoothed.iter().map(|p| p.value).fold(f64::INFINITY, f64::min);
        match self.last_smoothed() {
            Some(last) => best.is_finite() && last > best * factor + 1e-9 && last > best + 0.5,
            None => false,
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", self.name);
        for p in &self.points {
            s.push_str(&format!("{},{}\n", p.step, p.value));
        }
        s
    }

    /// Downsample to ~`n` points for terminal plotting.
    pub fn downsample(&self, n: usize) -> Vec<SeriesPoint> {
        if self.points.len() <= n || n == 0 {
            return self.points.clone();
        }
        let stride = self.points.len() as f64 / n as f64;
        (0..n)
            .map(|i| self.points[(i as f64 * stride) as usize].clone())
            .collect()
    }
}

/// ASCII sparkline for terminal loss curves.
pub fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|v| BARS[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_and_smooths() {
        let mut s = Series::new("loss", 0.5);
        for i in 0..10 {
            s.push(i, 10.0 - i as f64);
        }
        assert_eq!(s.points.len(), 10);
        assert_eq!(s.last(), Some(1.0));
        assert!(s.last_smoothed().unwrap() > 1.0); // EMA lags
    }

    #[test]
    fn capacity_hint_means_no_realloc_across_10k_pushes() {
        // Regression (PR-9): the trainer loop grew two Vecs per step with no
        // hint.  With a planned-steps hint, 10k pushes must never move
        // either buffer.
        let mut s = Series::with_capacity("g_loss", 0.05, 10_000);
        let p0 = s.points.as_ptr();
        let sm0 = s.smoothed.as_ptr();
        for i in 0..10_000 {
            s.push(i, i as f64 * 0.1);
        }
        assert_eq!(s.points.as_ptr(), p0, "points realloc'd despite hint");
        assert_eq!(s.smoothed.as_ptr(), sm0, "smoothed realloc'd despite hint");
        assert_eq!(s.points.capacity(), 10_000);
        assert_eq!(s.points.len(), 10_000);
    }

    #[test]
    fn unhinted_series_grows_in_chunks() {
        let mut s = Series::new("x", 0.1);
        for i in 0..(SERIES_CHUNK as u64) {
            s.push(i, 1.0);
        }
        // One chunk covers the first SERIES_CHUNK pushes: capacity is the
        // chunk size exactly, not a power-of-two doubling ladder.
        assert_eq!(s.points.capacity(), SERIES_CHUNK);
        assert_eq!(s.smoothed.capacity(), SERIES_CHUNK);
    }

    #[test]
    fn tail_statistics() {
        let mut s = Series::new("x", 0.1);
        for i in 0..100 {
            s.push(i, if i < 80 { 5.0 } else { 1.0 });
        }
        assert!((s.tail_mean(0.2) - 1.0).abs() < 1e-9);
        assert!(s.tail_std(0.2) < 1e-9);
    }

    #[test]
    fn collapse_detection() {
        let mut stable = Series::new("stable", 0.2);
        let mut collapsing = Series::new("collapse", 0.2);
        for i in 0..200 {
            stable.push(i, 1.0 + 0.01 * (i as f64).sin());
            // Collapses late: loss explodes after step 150.
            collapsing.push(i, if i < 150 { 1.0 } else { 1.0 + (i - 150) as f64 * 0.4 });
        }
        assert!(!stable.collapsed(2.0));
        assert!(collapsing.collapsed(2.0));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut s = Series::new("g_loss", 0.1);
        s.push(1, 0.5);
        s.push(2, 0.25);
        let csv = s.to_csv();
        assert!(csv.starts_with("step,g_loss\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn sparkline_monotone() {
        let sl = sparkline(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(sl.chars().count(), 4);
        assert!(sl.starts_with('▁') && sl.ends_with('█'));
    }

    #[test]
    fn downsample_preserves_endpoints_roughly() {
        let mut s = Series::new("x", 0.1);
        for i in 0..1000 {
            s.push(i, i as f64);
        }
        let d = s.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0].step, 0);
        assert!(d[9].step >= 900);
    }
}
