//! Frechet distance between Gaussian feature distributions (FID-proxy).
//!
//! FID(N(m1,C1), N(m2,C2)) = |m1-m2|^2 + tr(C1 + C2 - 2 (C1 C2)^{1/2}).
//!
//! The feature extractor is the fixed random conv net exported as the
//! `fid_features` HLO artifact (Inception-v3 substitution — DESIGN.md §1);
//! this module does the statistics.  The matrix square root uses
//! Newton–Schulz iteration on the symmetrized product
//! tr sqrt(C1 C2) = tr sqrt(C2^{1/2} C1 C2^{1/2}), which is PSD — all pure
//! matmuls, no eigensolver dependency.

/// Column-major-free tiny dense matrix (row-major `d x d`).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub d: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(d: usize) -> Mat {
        Mat { d, a: vec![0.0; d * d] }
    }
    pub fn eye(d: usize) -> Mat {
        let mut m = Mat::zeros(d);
        for i in 0..d {
            m.a[i * d + i] = 1.0;
        }
        m
    }
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.d + j]
    }
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.a[i * self.d + j]
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.d, other.d);
        let d = self.d;
        let mut out = Mat::zeros(d);
        for i in 0..d {
            for k in 0..d {
                let aik = self.at(i, k);
                if aik == 0.0 {
                    continue;
                }
                let row = &other.a[k * d..(k + 1) * d];
                let orow = &mut out.a[i * d..(i + 1) * d];
                for j in 0..d {
                    orow[j] += aik * row[j];
                }
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        for (o, x) in out.a.iter_mut().zip(&other.a) {
            *o += x;
        }
        out
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for o in out.a.iter_mut() {
            *o *= s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.d).map(|i| self.at(i, i)).sum()
    }

    pub fn frobenius(&self) -> f64 {
        self.a.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Newton–Schulz iteration for the principal square root of a PSD
    /// matrix.  Converges when the spectrum is scaled into (0, 2); we add a
    /// small ridge for rank-deficient sample covariances (e.g. conv
    /// features fitted from fewer samples than dimensions), and a
    /// convergence guard watches the residual `||ZY - I||_F`: the loop
    /// stops early once converged, and if the iteration starts diverging
    /// (near-singular spectra push eigenvalues of ZY outside the basin) the
    /// last stable iterate is returned instead of amplifying the blow-up.
    pub fn psd_sqrt(&self, iters: usize) -> Mat {
        let d = self.d;
        let ridge = 1e-8 * (self.trace() / d as f64).max(1e-12);
        let mut m = self.clone();
        for i in 0..d {
            *m.at_mut(i, i) += ridge;
        }
        let norm = m.frobenius().max(1e-30);
        let mut y = m.scale(1.0 / norm);
        let mut z = Mat::eye(d);
        // In the convergence basin the residual decreases monotonically, so
        // ANY increase (or a non-finite value) means the last update left
        // the basin: revert to the iterate from BEFORE that update — the
        // current y is the one the bad update produced.
        let mut prev_y = y.clone();
        let mut prev_res = f64::INFINITY;
        for _ in 0..iters {
            // Y <- Y (3I - Z Y)/2 ; Z <- (3I - Z Y)/2 Z
            let zy = z.matmul(&y);
            let mut res = 0.0;
            for i in 0..d {
                for j in 0..d {
                    let e = zy.at(i, j) - if i == j { 1.0 } else { 0.0 };
                    res += e * e;
                }
            }
            let res = res.sqrt();
            if !res.is_finite() || res > prev_res {
                y = prev_y; // diverging — return the last stable iterate
                break;
            }
            if res < 1e-12 {
                break; // converged
            }
            prev_res = res;
            prev_y = y.clone();
            let mut t = zy.scale(-1.0);
            for i in 0..d {
                *t.at_mut(i, i) += 3.0;
            }
            let t = t.scale(0.5);
            y = y.matmul(&t);
            z = t.matmul(&z);
        }
        y.scale(norm.sqrt())
    }
}

/// Gaussian statistics of a feature set: mean + covariance.
#[derive(Debug, Clone)]
pub struct FeatureStats {
    pub mean: Vec<f64>,
    pub cov: Mat,
    pub n: usize,
}

impl FeatureStats {
    /// `features`: row-major (n, d).
    pub fn fit(features: &[f32], d: usize) -> FeatureStats {
        assert!(d > 0 && features.len() % d == 0);
        let n = features.len() / d;
        assert!(n > 1, "need >= 2 samples for covariance");
        let mut mean = vec![0.0f64; d];
        for row in features.chunks_exact(d) {
            for (m, &x) in mean.iter_mut().zip(row) {
                *m += x as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mut cov = Mat::zeros(d);
        for row in features.chunks_exact(d) {
            for i in 0..d {
                let di = row[i] as f64 - mean[i];
                for j in i..d {
                    let dj = row[j] as f64 - mean[j];
                    *cov.at_mut(i, j) += di * dj;
                }
            }
        }
        // Mirror the upper triangle, unbiased estimator.
        for i in 0..d {
            for j in i..d {
                let v = cov.at(i, j) / (n - 1) as f64;
                *cov.at_mut(i, j) = v;
                *cov.at_mut(j, i) = v;
            }
        }
        FeatureStats { mean, cov, n }
    }
}

/// Frechet distance between two fitted feature distributions.
pub fn frechet_distance(a: &FeatureStats, b: &FeatureStats) -> f64 {
    assert_eq!(a.mean.len(), b.mean.len());
    let mean_term: f64 =
        a.mean.iter().zip(&b.mean).map(|(x, y)| (x - y) * (x - y)).sum();
    // tr sqrt(C1 C2) via the PSD symmetrization.
    let s = a.cov.psd_sqrt(24);
    let inner = s.matmul(&b.cov).matmul(&s);
    let tr_sqrt = inner.psd_sqrt(24).trace();
    (mean_term + a.cov.trace() + b.cov.trace() - 2.0 * tr_sqrt).max(0.0)
}

/// Inception-Score proxy: exp(mean KL(p(y|x) || p(y))) over mode-assignment
/// softmax distributions derived from feature-to-mode-center distances.
pub fn inception_score_proxy(features: &[f32], d: usize, centers: &[Vec<f64>]) -> f64 {
    let n = features.len() / d;
    let k = centers.len();
    if n == 0 || k == 0 {
        return 1.0;
    }
    let mut cond = vec![vec![0.0f64; k]; n];
    for (i, row) in features.chunks_exact(d).enumerate() {
        let mut logits: Vec<f64> = centers
            .iter()
            .map(|c| {
                let d2: f64 =
                    row.iter().zip(c).map(|(&x, &y)| (x as f64 - y) * (x as f64 - y)).sum();
                -d2
            })
            .collect();
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0;
        for l in logits.iter_mut() {
            *l = (*l - mx).exp();
            z += *l;
        }
        for (j, l) in logits.iter().enumerate() {
            cond[i][j] = l / z;
        }
    }
    let mut marginal = vec![0.0f64; k];
    for c in &cond {
        for (m, p) in marginal.iter_mut().zip(c) {
            *m += p / n as f64;
        }
    }
    let mut kl = 0.0;
    for c in &cond {
        for (p, q) in c.iter().zip(&marginal) {
            if *p > 1e-12 {
                kl += p * (p / q.max(1e-12)).ln() / n as f64;
            }
        }
    }
    kl.exp()
}

/// Mode coverage: fraction of `centers` that at least one feature row is
/// nearest to — the mode-collapse detector for the Fig. 13 experiments.
pub fn mode_coverage(features: &[f32], d: usize, centers: &[Vec<f64>]) -> f64 {
    let k = centers.len();
    if k == 0 {
        return 0.0;
    }
    let mut hit = vec![false; k];
    for row in features.chunks_exact(d) {
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for (j, c) in centers.iter().enumerate() {
            let d2: f64 =
                row.iter().zip(c).map(|(&x, &y)| (x as f64 - y) * (x as f64 - y)).sum();
            if d2 < best_d {
                best_d = d2;
                best = j;
            }
        }
        hit[best] = true;
    }
    hit.iter().filter(|h| **h).count() as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_features(rng: &mut Rng, n: usize, d: usize, mean: f32, std: f32) -> Vec<f32> {
        let mut v = vec![0f32; n * d];
        rng.fill_gaussian(&mut v, mean, std);
        v
    }

    #[test]
    fn psd_sqrt_of_diagonal() {
        let mut m = Mat::zeros(3);
        for (i, v) in [4.0, 9.0, 16.0].iter().enumerate() {
            *m.at_mut(i, i) = *v;
        }
        let s = m.psd_sqrt(30);
        for (i, v) in [2.0, 3.0, 4.0].iter().enumerate() {
            assert!((s.at(i, i) - v).abs() < 1e-4, "{:?}", s);
        }
    }

    #[test]
    fn psd_sqrt_squares_back() {
        let mut rng = Rng::new(3);
        let d = 8;
        // Random PSD: A A^T.
        let mut a = Mat::zeros(d);
        for v in a.a.iter_mut() {
            *v = rng.gaussian();
        }
        let mut at = Mat::zeros(d);
        for i in 0..d {
            for j in 0..d {
                *at.at_mut(i, j) = a.at(j, i);
            }
        }
        let psd = a.matmul(&at);
        let s = psd.psd_sqrt(40);
        let back = s.matmul(&s);
        let err = back.add(&psd.scale(-1.0)).frobenius() / psd.frobenius();
        assert!(err < 1e-3, "relative err {err}");
    }

    #[test]
    fn psd_sqrt_survives_near_singular_covariance() {
        // Rank-1 covariance (all samples on a line): the un-guarded
        // iteration wanders once the tiny ridge eigenvalues leave the
        // convergence basin; the guard must return a finite square root
        // that still squares back to the matrix within a loose tolerance.
        let d = 8;
        let mut v = Mat::zeros(d);
        for i in 0..d {
            for j in 0..d {
                *v.at_mut(i, j) = ((i + 1) * (j + 1)) as f64 / d as f64;
            }
        }
        let s = v.psd_sqrt(60);
        assert!(s.a.iter().all(|x| x.is_finite()));
        let back = s.matmul(&s);
        let err = back.add(&v.scale(-1.0)).frobenius() / v.frobenius().max(1e-12);
        assert!(err < 0.05, "relative err {err}");
        // And a fully singular (zero) matrix is a no-op, not a NaN.
        let z = Mat::zeros(4).psd_sqrt(30);
        assert!(z.a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn fid_zero_for_identical_distributions() {
        let mut rng = Rng::new(1);
        let f = gaussian_features(&mut rng, 4000, 8, 0.0, 1.0);
        let a = FeatureStats::fit(&f, 8);
        let fid = frechet_distance(&a, &a);
        assert!(fid < 1e-3, "{fid}");
    }

    #[test]
    fn fid_detects_mean_shift_quadratically() {
        let mut rng = Rng::new(2);
        let a = FeatureStats::fit(&gaussian_features(&mut rng, 6000, 6, 0.0, 1.0), 6);
        let b1 = FeatureStats::fit(&gaussian_features(&mut rng, 6000, 6, 1.0, 1.0), 6);
        let b2 = FeatureStats::fit(&gaussian_features(&mut rng, 6000, 6, 2.0, 1.0), 6);
        let f1 = frechet_distance(&a, &b1);
        let f2 = frechet_distance(&a, &b2);
        // |dm|^2 = d * shift^2: 6 and 24.
        assert!((f1 - 6.0).abs() < 1.0, "{f1}");
        assert!((f2 - 24.0).abs() < 3.0, "{f2}");
    }

    #[test]
    fn fid_detects_variance_collapse() {
        // Mode collapse shrinks the generator's feature covariance.
        let mut rng = Rng::new(4);
        let real = FeatureStats::fit(&gaussian_features(&mut rng, 6000, 6, 0.0, 1.0), 6);
        let collapsed = FeatureStats::fit(&gaussian_features(&mut rng, 6000, 6, 0.0, 0.1), 6);
        let fid = frechet_distance(&real, &collapsed);
        // tr(C1) + tr(C2) - 2 tr sqrt(C1C2) = 6(1 + .01 - 2*.1) = 4.86.
        assert!((fid - 4.86).abs() < 0.6, "{fid}");
        assert!(fid > frechet_distance(&real, &real) + 1.0);
    }

    #[test]
    fn fid_symmetric() {
        let mut rng = Rng::new(5);
        let a = FeatureStats::fit(&gaussian_features(&mut rng, 3000, 5, 0.0, 1.0), 5);
        let b = FeatureStats::fit(&gaussian_features(&mut rng, 3000, 5, 0.7, 1.4), 5);
        let ab = frechet_distance(&a, &b);
        let ba = frechet_distance(&b, &a);
        assert!((ab - ba).abs() / ab < 0.02, "{ab} vs {ba}");
    }

    #[test]
    fn is_proxy_higher_for_diverse_samples() {
        let centers: Vec<Vec<f64>> =
            (0..4).map(|k| (0..3).map(|j| if j == k % 3 { 5.0 } else { 0.0 }).collect()).collect();
        // Diverse: rows near all 4 centers.
        let mut diverse = Vec::new();
        for k in 0..4 {
            for _ in 0..25 {
                for j in 0..3 {
                    diverse.push(if j == k % 3 { 5.0 } else { 0.0 });
                }
            }
        }
        // Collapsed: all rows near center 0.
        let collapsed: Vec<f32> =
            (0..100).flat_map(|_| vec![5.0f32, 0.0, 0.0]).collect();
        let is_d = inception_score_proxy(&diverse, 3, &centers);
        let is_c = inception_score_proxy(&collapsed, 3, &centers);
        assert!(is_d > is_c, "diverse {is_d} collapsed {is_c}");
    }

    #[test]
    fn mode_coverage_detects_collapse() {
        let centers: Vec<Vec<f64>> = (0..8)
            .map(|k| (0..4).map(|j| if j == k % 4 { k as f64 + 1.0 } else { 0.0 }).collect())
            .collect();
        let all: Vec<f32> = centers.iter().flat_map(|c| c.iter().map(|&x| x as f32)).collect();
        assert_eq!(mode_coverage(&all, 4, &centers), 1.0);
        let one: Vec<f32> = centers[0].iter().map(|&x| x as f32).collect();
        assert_eq!(mode_coverage(&one, 4, &centers), 1.0 / 8.0);
    }
}
