//! Whole-model MXU utilization accounting (feeds Fig. 10 and the cluster
//! simulator's compute model).
//!
//! A conv layer on TPU is an im2col matmul: rows = B*OH*OW, K = Cin*kh*kw,
//! N = Cout.  The *layout transformation* changes how those matmuls are
//! shaped:
//!
//!   * native: each sample's activations are fed as they arrive — matmuls
//!     run at per-sample granularity (M = OH*OW), so small dense/head
//!     layers pad 1 row up to the 8-row sublane, and row padding is paid
//!     per sample;
//!   * ParaGAN: the batch dimension is folded in (M = B*OH*OW) and
//!     same-weight matmuls are opportunistically concatenated, so padding
//!     is amortized across the whole batch (paper: "tries to batch them
//!     such that N/H/W are multiple of 128").
//!
//! Both estimates run through the SAME `MatmulPlan` code — the deltas are
//! produced by the planner, not scripted.

use std::ops::Range;

use super::plan::{
    host_peak_flops, Accelerator, KernelLane, MatmulPlan, EXCHANGE_BUCKET_MIN_BYTES,
    EXCHANGE_BUCKET_TARGET_NS,
};

/// One layer of a model, described as its im2col matmul per sample.
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub name: String,
    /// Matmul rows contributed by ONE sample (OH*OW for conv, 1 for dense).
    pub m_per_sample: usize,
    pub k: usize,
    pub n: usize,
    /// How many times the layer runs per training step (fwd + bwd passes).
    pub repeats: usize,
}

impl LayerShape {
    /// General conv layer: `(kh, kw)` kernel producing an `(oh, ow)` output
    /// map — non-square kernels and non-square intermediates (e.g. the
    /// dcgan32-derived 4x4-kernel shapes, or padded rectangles) cost
    /// correctly through the same im2col accounting.
    pub fn conv_rect(
        name: &str,
        cin: usize,
        cout: usize,
        (kh, kw): (usize, usize),
        (oh, ow): (usize, usize),
    ) -> LayerShape {
        LayerShape {
            name: name.to_string(),
            m_per_sample: oh * ow,
            k: cin * kh * kw,
            n: cout,
            repeats: 3, // fwd + dgrad + wgrad
        }
    }

    /// Square-kernel, square-output shorthand for `conv_rect`.
    pub fn conv(name: &str, cin: usize, cout: usize, kh: usize, oh: usize) -> LayerShape {
        LayerShape::conv_rect(name, cin, cout, (kh, kh), (oh, oh))
    }

    pub fn dense(name: &str, fin: usize, fout: usize) -> LayerShape {
        LayerShape { name: name.to_string(), m_per_sample: 1, k: fin, n: fout, repeats: 3 }
    }

    pub fn flops_per_sample(&self) -> f64 {
        2.0 * self.m_per_sample as f64 * self.k as f64 * self.n as f64 * self.repeats as f64
    }
}

#[derive(Debug, Clone)]
pub struct UtilizationReport {
    /// Useful FLOPs per training step.
    pub real_flops: f64,
    /// MXU-occupied FLOPs per step including padding waste.
    pub padded_flops: f64,
    /// real / padded.
    pub mxu_occupancy: f64,
    /// Per-layer (name, occupancy).
    pub per_layer: Vec<(String, f64)>,
}

/// Estimate a model's MXU occupancy for a training step.
pub fn model_mxu_utilization(
    layers: &[LayerShape],
    batch: usize,
    acc: Accelerator,
    elem_bytes: usize,
    layout_transform: bool,
) -> UtilizationReport {
    let mut real = 0.0;
    let mut padded = 0.0;
    let mut per_layer = Vec::with_capacity(layers.len());
    for l in layers {
        let reps = l.repeats as f64;
        // Convolutions are batched by XLA either way; the layout pass
        // additionally folds the batch into SMALL (dense/FiLM/head) matmuls
        // via opportunistic concatenation (paper §4.2) — natively those run
        // per sample and pay row padding + pipeline under-fill `batch` times.
        let fold = layout_transform || l.m_per_sample > 1;
        let (lr, lp) = if fold {
            let p = MatmulPlan::for_accel(acc, l.m_per_sample * batch, l.k, l.n, elem_bytes);
            (p.real_flops() * reps, p.mxu_cost_flops() * reps)
        } else {
            let p = MatmulPlan::for_accel(acc, l.m_per_sample, l.k, l.n, elem_bytes);
            (p.real_flops() * reps * batch as f64, p.mxu_cost_flops() * reps * batch as f64)
        };
        per_layer.push((l.name.clone(), lr / lp));
        real += lr;
        padded += lp;
    }
    UtilizationReport {
        real_flops: real,
        padded_flops: padded,
        mxu_occupancy: if padded > 0.0 { real / padded } else { 1.0 },
        per_layer,
    }
}

// ---------------------------------------------------------------------------
// Per-lane host GEMM cost model — one cost model, many targets
// ---------------------------------------------------------------------------

/// Sustained host stream bandwidth assumed for packing traffic (bytes/sec).
/// Deliberately conservative (~20 GB/s, one DDR4/DDR5 channel's worth of
/// sustained copy) — like [`super::plan::host_peak_flops`] this is a
/// *relative* model for lane/shape comparisons, not a measured number.
pub const HOST_STREAM_BYTES_PER_SEC: f64 = 2.0e10;

/// Cost-model verdict for one GEMM on one host kernel lane.
#[derive(Debug, Clone, Copy)]
pub struct HostLaneEstimate {
    pub lane: KernelLane,
    /// FLOPs the lane actually executes, including its tile padding
    /// (wider `nr` pads small-n shapes harder on the SIMD lane).
    pub padded_flops: f64,
    /// That lane's [`host_peak_flops`] ceiling.
    pub peak_flops: f64,
    /// Bytes touched packing A + B panels and writing C once.
    pub pack_bytes: f64,
    /// Modeled wall time: compute at lane peak + packing at stream bandwidth.
    pub est_ns: f64,
}

/// Model one GEMM on a host lane.  Builds on [`MatmulPlan::for_host_lane`]
/// (so padding follows that lane's [`super::plan::CpuTileRule`] exactly) and
/// [`host_peak_flops`] (so the FLOP ceiling matches the lane's issue width).
/// The roofline-style sum — compute at peak plus packing traffic at stream
/// bandwidth — is what lets the planner see that doubling peak FLOPs does
/// NOT halve the cost of a shape whose padded work also doubles.
pub fn host_gemm_estimate(lane: KernelLane, m: usize, k: usize, n: usize) -> HostLaneEstimate {
    let p = MatmulPlan::for_host_lane(lane, m, k, n);
    let padded = p.padded_flops();
    let peak = host_peak_flops(lane);
    // Packed A panels (mp*kp) + packed B panels (kp*np) + one C write (mp*np),
    // all f32 — the same volume `runtime::workspace` actually reserves.
    let pack_bytes = ((p.mp * p.kp + p.kp * p.np + p.mp * p.np) * p.elem_bytes) as f64;
    let est_ns = padded / peak * 1e9 + pack_bytes / HOST_STREAM_BYTES_PER_SEC * 1e9;
    HostLaneEstimate { lane, padded_flops: padded, peak_flops: peak, pack_bytes, est_ns }
}

/// The lane the cost model would pick for this shape: argmin of
/// [`host_gemm_estimate`] across both lanes, ties to the exact lane (it is
/// the default and the parity oracle).  This is a *model* verdict — runtime
/// lane selection additionally requires the fast lane to be requested
/// (`PARAGAN_KERNEL=simd` / `TrainConfig::precision_mode`) and usable
/// (`runtime::kernel::simd_available`, `PARAGAN_SIMD=off` escape hatch).
pub fn preferred_host_lane(m: usize, k: usize, n: usize) -> KernelLane {
    let exact = host_gemm_estimate(KernelLane::Exact, m, k, n);
    let simd = host_gemm_estimate(KernelLane::Simd, m, k, n);
    if simd.est_ns < exact.est_ns {
        KernelLane::Simd
    } else {
        KernelLane::Exact
    }
}

// ---------------------------------------------------------------------------
// Gradient-exchange bucket planning — the overlap lane's sizing policy
// ---------------------------------------------------------------------------

/// Bytes one gradient-exchange bucket should carry: the bucket wall-time
/// target ([`EXCHANGE_BUCKET_TARGET_NS`]) at the modeled stream bandwidth
/// ([`HOST_STREAM_BYTES_PER_SEC`]), floored at
/// [`EXCHANGE_BUCKET_MIN_BYTES`].  Like every number in this module it is a
/// *relative* sizing verdict, not a measurement — what matters is that the
/// same policy yields the same plan on every replica.
pub fn exchange_bucket_bytes() -> usize {
    let wire = EXCHANGE_BUCKET_TARGET_NS as f64 * 1e-9 * HOST_STREAM_BYTES_PER_SEC;
    (wire as usize).max(EXCHANGE_BUCKET_MIN_BYTES)
}

/// Partition gradient tensors (given in COMPLETION order, sizes in bytes)
/// into consecutive exchange buckets: greedy accumulation until a bucket
/// reaches [`exchange_bucket_bytes`], never splitting a tensor.  The plan
/// is a pure function of the sizes, so replicas that observe the same
/// completion order (they do — it is the model's backward order) compute
/// identical plans and meet on the exchange barrier bucket for bucket.
///
/// Every tensor lands in exactly one bucket, buckets are non-empty and
/// cover `0..sizes.len()` in order; an empty input yields an empty plan.
pub fn bucket_plan(sizes_bytes: &[usize]) -> Vec<Range<usize>> {
    let target = exchange_bucket_bytes();
    let mut plan = Vec::new();
    let mut start = 0usize;
    let mut filled = 0usize;
    for (i, &sz) in sizes_bytes.iter().enumerate() {
        // Close the open bucket BEFORE an add that already met the target:
        // oversized single tensors get a bucket of their own.
        if filled >= target && i > start {
            plan.push(start..i);
            start = i;
            filled = 0;
        }
        filled += sz;
    }
    if start < sizes_bytes.len() {
        plan.push(start..sizes_bytes.len());
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall_cases, gens};

    fn toy_model() -> Vec<LayerShape> {
        vec![
            LayerShape::conv("c1", 3, 64, 4, 16),
            LayerShape::conv("c2", 64, 128, 4, 8),
            LayerShape::dense("head", 2048, 1),
        ]
    }

    #[test]
    fn layout_transform_improves_occupancy() {
        let layers = toy_model();
        let native = model_mxu_utilization(&layers, 32, Accelerator::TpuV3, 2, false);
        let ours = model_mxu_utilization(&layers, 32, Accelerator::TpuV3, 2, true);
        assert!(
            ours.mxu_occupancy > native.mxu_occupancy,
            "ours {} native {}",
            ours.mxu_occupancy,
            native.mxu_occupancy
        );
        // Useful FLOPs are identical — only padding differs.
        assert!((ours.real_flops - native.real_flops).abs() / native.real_flops < 1e-12);
    }

    #[test]
    fn dense_head_is_the_padding_hotspot_natively() {
        let layers = toy_model();
        let native = model_mxu_utilization(&layers, 32, Accelerator::TpuV3, 2, false);
        let head = native.per_layer.iter().find(|(n, _)| n == "head").unwrap().1;
        // One row padded to the 8-row sublane: at most 1/8 useful.
        assert!(head <= 0.125 + 1e-9, "{head}");
    }

    #[test]
    fn prop_occupancy_in_unit_interval_and_batch_monotone() {
        forall_cases(gens::usize_in(1..128), 64, |&batch| {
            let layers = toy_model();
            let r = model_mxu_utilization(&layers, batch, Accelerator::TpuV3, 2, true);
            let r2 = model_mxu_utilization(&layers, batch * 2, Accelerator::TpuV3, 2, true);
            r.mxu_occupancy > 0.0
                && r.mxu_occupancy <= 1.0
                && r2.mxu_occupancy >= r.mxu_occupancy - 0.05 // folding more batch never hurts much
        });
    }

    #[test]
    fn conv_rect_accepts_nonsquare_kernels_and_outputs() {
        let r = LayerShape::conv_rect("r", 16, 32, (4, 3), (8, 5));
        assert_eq!(r.m_per_sample, 40);
        assert_eq!(r.k, 16 * 12);
        assert_eq!(r.n, 32);
        // The square shorthand is exactly the rect special case.
        let sq = LayerShape::conv("s", 16, 32, 4, 8);
        let rq = LayerShape::conv_rect("s", 16, 32, (4, 4), (8, 8));
        assert_eq!((sq.m_per_sample, sq.k, sq.n, sq.repeats), (rq.m_per_sample, rq.k, rq.n, rq.repeats));
    }

    /// The utilization model consumes the HostCpu rule like any other
    /// accelerator: its 4x8 register tiles pad far less than the 8x128 TPU
    /// sublane/lane rule, so the same model reports higher occupancy on the
    /// CPU engine — and the layout transform still never hurts.
    #[test]
    fn host_cpu_rule_flows_through_utilization_model() {
        let layers = toy_model();
        let cpu = model_mxu_utilization(&layers, 32, Accelerator::HostCpu, 4, true);
        let tpu = model_mxu_utilization(&layers, 32, Accelerator::TpuV3, 4, true);
        assert!(cpu.mxu_occupancy > 0.0 && cpu.mxu_occupancy <= 1.0);
        assert!(
            cpu.mxu_occupancy >= tpu.mxu_occupancy,
            "cpu {} tpu {}",
            cpu.mxu_occupancy,
            tpu.mxu_occupancy
        );
        let native = model_mxu_utilization(&layers, 32, Accelerator::HostCpu, 4, false);
        assert!(cpu.mxu_occupancy >= native.mxu_occupancy - 1e-12);
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let layers = toy_model();
        let r1 = model_mxu_utilization(&layers, 16, Accelerator::TpuV3, 2, true);
        let r2 = model_mxu_utilization(&layers, 32, Accelerator::TpuV3, 2, true);
        assert!((r2.real_flops / r1.real_flops - 2.0).abs() < 1e-9);
    }

    /// The cost model prefers the SIMD lane on the big dcgan32 conv GEMM
    /// (m = B*OH*OW = 64*16*16, k = 3*4*4, n = 64): n is already a multiple
    /// of both lanes' `nr`, so padded work is identical, peak doubles, and
    /// packing traffic is the same — the fast lane strictly wins.  These
    /// verdicts are core-count independent (the unknown core count scales
    /// both lanes' peaks equally).
    #[test]
    fn cost_model_prefers_simd_lane_on_dcgan32_conv_shapes() {
        let (m, k, n) = (64 * 16 * 16, 3 * 4 * 4, 64);
        assert_eq!(preferred_host_lane(m, k, n), KernelLane::Simd);
        let e = host_gemm_estimate(KernelLane::Exact, m, k, n);
        let s = host_gemm_estimate(KernelLane::Simd, m, k, n);
        assert!(s.est_ns > 0.0 && e.est_ns > 0.0);
        assert!((s.padded_flops - e.padded_flops).abs() < 1e-6, "n=64 pads neither lane");
        assert!(s.est_ns < e.est_ns, "simd {} exact {}", s.est_ns, e.est_ns);
    }

    /// Tiny-n shapes (the FID head projects to n = 1) go the other way: the
    /// SIMD lane's wider `nr` doubles the padded work, cancelling its doubled
    /// peak, while its wider packed-B panels cost MORE packing traffic — so
    /// the model keeps the exact lane.  One cost model, two verdicts.
    #[test]
    fn cost_model_keeps_exact_lane_for_tiny_n_shapes() {
        assert_eq!(preferred_host_lane(4, 17, 1), KernelLane::Exact);
        let e = host_gemm_estimate(KernelLane::Exact, 4, 17, 1);
        let s = host_gemm_estimate(KernelLane::Simd, 4, 17, 1);
        assert!(s.pack_bytes > e.pack_bytes, "wider nr packs more: {} vs {}", s.pack_bytes, e.pack_bytes);
        assert!(e.est_ns <= s.est_ns, "exact {} simd {}", e.est_ns, s.est_ns);
    }

    /// The bucket target derives from the SAME bandwidth model as the GEMM
    /// packing estimate, and never dips below the rendezvous floor.
    #[test]
    fn exchange_bucket_bytes_matches_the_bandwidth_model() {
        let b = exchange_bucket_bytes();
        assert!(b >= crate::layout::plan::EXCHANGE_BUCKET_MIN_BYTES);
        let wire = crate::layout::plan::EXCHANGE_BUCKET_TARGET_NS as f64 * 1e-9
            * HOST_STREAM_BYTES_PER_SEC;
        assert_eq!(b, (wire as usize).max(crate::layout::plan::EXCHANGE_BUCKET_MIN_BYTES));
    }

    /// bucket_plan covers the input exactly once, in order, with non-empty
    /// consecutive buckets; every bucket except the last meets the target
    /// unless a single oversized tensor owns it.
    #[test]
    fn prop_bucket_plan_covers_in_order() {
        forall_cases(gens::usize_in(0..40), 64, |&n| {
            let sizes: Vec<usize> =
                (0..n).map(|i| (i * 7919 + 13) % (3 * exchange_bucket_bytes() / 2)).collect();
            let plan = bucket_plan(&sizes);
            let mut next = 0usize;
            for r in &plan {
                if r.start != next || r.is_empty() {
                    return false;
                }
                next = r.end;
            }
            next == n && (n > 0) == !plan.is_empty()
        });
    }

    /// The greedy close point: a bucket closes only once it has met the
    /// target, so oversized tensors travel alone and small tails merge.
    #[test]
    fn bucket_plan_groups_to_target_and_isolates_oversized_tensors() {
        let t = exchange_bucket_bytes();
        assert_eq!(bucket_plan(&[]), Vec::<Range<usize>>::new());
        assert_eq!(bucket_plan(&[1]), vec![0..1]);
        // Three tensors of 0.6*target: first two share, tail is its own.
        let s = 3 * t / 5;
        assert_eq!(bucket_plan(&[s, s, s]), vec![0..2, 2..3]);
        // An oversized tensor closes its bucket before the next tensor.
        assert_eq!(bucket_plan(&[5 * t, 1, 1]), vec![0..1, 1..3]);
        // Everything under target collapses into one bucket.
        assert_eq!(bucket_plan(&[1, 2, 3]), vec![0..3]);
    }

    /// Estimates stay positive and finite across a shape sweep, and the
    /// lane peaks pin to the documented 2x issue-width ratio.
    #[test]
    fn prop_host_lane_estimates_positive_and_peak_ratio_pinned() {
        forall_cases(gens::usize_in(1..200), 64, |&s| {
            let (m, k, n) = (s, (s % 31) + 1, (s % 17) + 1);
            let e = host_gemm_estimate(KernelLane::Exact, m, k, n);
            let f = host_gemm_estimate(KernelLane::Simd, m, k, n);
            e.est_ns.is_finite()
                && e.est_ns > 0.0
                && f.est_ns.is_finite()
                && f.est_ns > 0.0
                && (f.peak_flops / e.peak_flops - 2.0).abs() < 1e-12
                && f.padded_flops >= e.padded_flops
        });
    }
}
