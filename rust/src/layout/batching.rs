//! Opportunistic batching (paper §4.2).
//!
//! "On top of the batch dimensions, ParaGAN also seeks opportunities to
//! batch intermediate results to be a multiple of optimal layout dimensions.
//! Such opportunities can be found at reshape and matmul operators. For
//! instance, if two input matrices are to multiply the same weight, we can
//! concatenate the two input matrices before the matrix multiplication
//! operation to save kernel launch overhead."
//!
//! Given a stream of pending matmuls (each: M rows against a named weight),
//! the planner groups same-weight matmuls and decides which groups to fuse:
//! fusing is profitable when it reduces padded FLOPs (shared row padding)
//! or when the saved kernel-launch overhead exceeds the concat cost.

use std::collections::BTreeMap;

use super::plan::{round_up, Accelerator, MatmulPlan};

/// One pending matmul: `rows x k` times weight `k x n`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PendingMatmul {
    pub weight: String,
    pub rows: usize,
    pub k: usize,
    pub n: usize,
}

/// A planned fusion group.
#[derive(Debug, Clone)]
pub struct BatchOpportunity {
    pub weight: String,
    /// Indices into the input list, in input order.
    pub members: Vec<usize>,
    pub fused_rows: usize,
    /// Padded-FLOP saving vs running members separately (>= 0 when fused).
    pub flops_saved: f64,
    /// Kernel launches eliminated.
    pub launches_saved: usize,
}

/// Group same-weight matmuls and fuse every group where fusing does not
/// increase padded FLOPs (it never does for same-k/n: row padding is
/// amortized), reporting the savings.
pub fn plan_opportunistic_batches(
    acc: Accelerator,
    elem_bytes: usize,
    pending: &[PendingMatmul],
) -> Vec<BatchOpportunity> {
    let mut groups: BTreeMap<(String, usize, usize), Vec<usize>> = BTreeMap::new();
    for (i, p) in pending.iter().enumerate() {
        groups.entry((p.weight.clone(), p.k, p.n)).or_default().push(i);
    }
    let mut out = Vec::new();
    let rule = acc.tile_rule(elem_bytes);
    for ((weight, k, n), members) in groups {
        if members.len() < 2 {
            continue;
        }
        let fused_rows: usize = members.iter().map(|&i| pending[i].rows).sum();
        let sep_padded: f64 = members
            .iter()
            .map(|&i| MatmulPlan::for_accel(acc, pending[i].rows, k, n, elem_bytes).padded_flops())
            .sum();
        let fused_padded =
            MatmulPlan::for_accel(acc, fused_rows, k, n, elem_bytes).padded_flops();
        let flops_saved = sep_padded - fused_padded;
        // Same-k/n fusion can only reduce row padding; fuse whenever it does
        // not hurt (flops_saved >= 0 always holds, asserted in tests).
        out.push(BatchOpportunity {
            weight,
            members: members.clone(),
            fused_rows: round_up(fused_rows, rule.row),
            flops_saved,
            launches_saved: members.len() - 1,
        });
    }
    out
}

/// Total padded-FLOP fraction saved by the plan over the naive execution.
pub fn fused_savings_fraction(
    acc: Accelerator,
    elem_bytes: usize,
    pending: &[PendingMatmul],
) -> f64 {
    let naive: f64 = pending
        .iter()
        .map(|p| MatmulPlan::for_accel(acc, p.rows, p.k, p.n, elem_bytes).padded_flops())
        .sum();
    if naive == 0.0 {
        return 0.0;
    }
    let saved: f64 = plan_opportunistic_batches(acc, elem_bytes, pending)
        .iter()
        .map(|b| b.flops_saved)
        .sum();
    saved / naive
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall_cases, gens};

    fn mm(weight: &str, rows: usize) -> PendingMatmul {
        PendingMatmul { weight: weight.into(), rows, k: 256, n: 128 }
    }

    #[test]
    fn fuses_same_weight_only() {
        let pending = vec![mm("w1", 10), mm("w2", 20), mm("w1", 30)];
        let plan = plan_opportunistic_batches(Accelerator::TpuV3, 4, &pending);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].weight, "w1");
        assert_eq!(plan[0].members, vec![0, 2]);
        assert_eq!(plan[0].launches_saved, 1);
    }

    #[test]
    fn paper_example_two_small_inputs_save_padding() {
        // Two 4-row inputs each pad to 8 rows separately; fused 8 rows pad to 8.
        let pending = vec![mm("w", 4), mm("w", 4)];
        let plan = plan_opportunistic_batches(Accelerator::TpuV3, 4, &pending);
        assert_eq!(plan.len(), 1);
        assert!(plan[0].flops_saved > 0.0);
        assert_eq!(plan[0].fused_rows, 8);
    }

    #[test]
    fn different_k_or_n_never_fused() {
        let pending = vec![
            PendingMatmul { weight: "w".into(), rows: 4, k: 256, n: 128 },
            PendingMatmul { weight: "w".into(), rows: 4, k: 512, n: 128 },
        ];
        let plan = plan_opportunistic_batches(Accelerator::TpuV3, 4, &pending);
        assert!(plan.is_empty());
    }

    #[test]
    fn prop_fusion_never_increases_padded_flops() {
        forall_cases(gens::vec(gens::usize_in(1..100), 2..12), 128, |rows| {
            let pending: Vec<PendingMatmul> = rows.iter().map(|&r| mm("w", r)).collect();
            let plan = plan_opportunistic_batches(Accelerator::TpuV3, 4, &pending);
            plan.iter().all(|b| b.flops_saved >= -1e-6)
        });
    }

    #[test]
    fn prop_savings_fraction_bounded() {
        forall_cases(gens::vec(gens::usize_in(1..64), 0..10), 128, |rows| {
            let pending: Vec<PendingMatmul> = rows.iter().map(|&r| mm("w", r)).collect();
            let f = fused_savings_fraction(Accelerator::TpuV3, 4, &pending);
            (0.0..=1.0).contains(&f)
        });
    }
}
