//! Hardware-aware layout transformation (paper §4.2) — the rust-side planner.
//!
//! Mirrors `python/compile/kernels/layout_matmul.py`: the same (sublane,
//! lane) tiling rules, padding plans, VMEM budgeting and MXU-occupancy
//! accounting, extended with
//!
//!   * per-accelerator tile rules (TPU v3, V100, A100 — paper §3.3),
//!   * opportunistic batching of same-weight matmuls (paper: "if two input
//!     matrices are to multiply the same weight, we can concatenate"),
//!   * whole-model utilization estimates the cluster simulator and the
//!     Fig. 10 experiment consume.

pub mod batching;
pub mod cost;
pub mod plan;

pub use batching::{plan_opportunistic_batches, BatchOpportunity};
pub use cost::{host_gemm_estimate, model_mxu_utilization, preferred_host_lane, HostLaneEstimate, LayerShape, UtilizationReport};
pub use plan::{Accelerator, KernelLane, MatmulPlan, TileRule};
