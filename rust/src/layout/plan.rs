//! Padding/tiling plans per accelerator.
//!
//! Paper §3.3: "Nvidia A100 GPUs prefer half-precision data in multiples of
//! 64, and single-precision data in multiples of 32, while previous
//! generations prefer multiples of 8. For TPU, the preferred data layout
//! should have a multiple of 128 on the lane dimension and 8 on the sublane
//! dimension."

/// TPU v3 per-core VMEM is 16 MiB; plan against half for double-buffering
/// (matches the python planner).
pub const VMEM_BUDGET_BYTES: usize = 8 * 1024 * 1024;

/// MXU systolic array dimension (TPU v2/v3: 128x128).
pub const MXU_DIM: usize = 128;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accelerator {
    /// TPU v2/v3: (sublane=8, lane=128).
    TpuV3,
    /// V100: tensor-core era, multiples of 8.
    V100,
    /// A100: fp16 multiples of 64, fp32 multiples of 32.
    A100,
    /// The host CPU running the `RefCpuBackend` — the one accelerator this
    /// planner does not merely *model* but actually *drives*: the tiles it
    /// picks here are the register blocks `runtime::kernel::Gemm` executes
    /// (see [`CpuTileRule`]).
    HostCpu,
}

/// Register micro-tile of the CPU GEMM engine: MR rows of A are held
/// against NR columns of B in an MR x NR f32 accumulator block (32 scalars
/// — comfortably register-resident; NR=8 matches one 256-bit f32 vector so
/// the inner loop autovectorizes).
pub const CPU_MR: usize = 4;
pub const CPU_NR: usize = 8;

/// The host GEMM engine's execution lanes.  `Exact` is the default and the
/// parity oracle: scalar separate-mul-add, single ascending-K chain, bit
/// identical to `kernel::naive` (the PR-3 contract).  `Simd` is the opt-in
/// FMA fast lane (`PARAGAN_KERNEL=simd` / `TrainConfig::precision_mode`):
/// fused multiply-add over wider register tiles with a fixed multi-chain K
/// split — deterministic for a given lane and thread count, but NOT
/// bit-equal to the oracle; it ships a documented relative-error bound
/// instead (`runtime::kernel::fast_lane_abs_tol`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelLane {
    Exact,
    Simd,
}

impl KernelLane {
    pub fn name(self) -> &'static str {
        match self {
            KernelLane::Exact => "exact",
            KernelLane::Simd => "simd",
        }
    }
}

/// Fast-lane micro-tile.  The A-panel height is deliberately the SAME as
/// the exact lane's (`CPU_MR`) so packed A buffers and the im2col direct
/// packers are lane-invariant; only the B-panel width and the K-chain
/// depth differ per lane.
pub const CPU_SIMD_MR: usize = CPU_MR;

/// Fast-lane B-panel width: two f32 vectors per accumulator row (AVX2:
/// 2 x 8 lanes = 16; NEON: 2 x 4 = 8), twice the exact lane's single
/// autovectorized vector — the "wider nr" the FMA kernel's extra
/// throughput needs to stay fed.
#[cfg(target_arch = "aarch64")]
pub const CPU_SIMD_NR: usize = 8;
#[cfg(not(target_arch = "aarch64"))]
pub const CPU_SIMD_NR: usize = 16;

/// Fast-lane K-chain depth: each output element accumulates through this
/// many independent fused-multiply-add chains (chain `u` takes the K terms
/// with `kk % CPU_SIMD_KU == u`), combined in ascending chain order at the
/// end.  A FIXED split: the summation tree depends only on the lane and K,
/// never on the thread count or tile traversal — the fast lane stays
/// deterministic (`runtime::kernel` pins it).
pub const CPU_SIMD_KU: usize = 2;

/// f32 lanes per vector register the fast lane assumes after feature
/// detection (AVX2 ymm: 8, NEON: 4) — the issue-width input to
/// [`host_peak_flops`].
#[cfg(target_arch = "aarch64")]
pub const CPU_SIMD_LANES: usize = 4;
#[cfg(not(target_arch = "aarch64"))]
pub const CPU_SIMD_LANES: usize = 8;

/// Widest B-panel any lane packs to — workspace memory plans size packed-B
/// scratch with this so one plan covers every lane the process may select.
pub const CPU_NR_ANY: usize = if CPU_NR > CPU_SIMD_NR { CPU_NR } else { CPU_SIMD_NR };

// The lane-invariant contracts the packers rely on, checked at compile
// time: shared A-panel height, covering B width.
const _: () = assert!(CPU_SIMD_MR == CPU_MR, "lanes must share the A-panel height");
const _: () = assert!(CPU_NR_ANY >= CPU_NR && CPU_NR_ANY >= CPU_SIMD_NR);
const _: () = assert!(CPU_SIMD_KU >= 1);

/// Cache share the packed B block may occupy while A panels stream past it
/// — the CPU analog of the VMEM budget above (a conservative L2 slice).
pub const CPU_CACHE_BUDGET_BYTES: usize = 192 * 1024;

/// Cache share of the packed A row block a worker holds against one
/// resident B block (the `mc_rows` row-blocking budget — a conservative
/// L2 slice alongside [`CPU_CACHE_BUDGET_BYTES`]).
pub const CPU_A_BLOCK_BUDGET_BYTES: usize = 96 * 1024;

/// Target wall time ONE gradient bucket should occupy on the exchange wire
/// (nanoseconds).  The overlapped dist lane (`dist::overlap`) streams
/// finished per-layer gradients into `Exchange::all_reduce_mean_into` in
/// consecutive completion-order buckets; this constant times the modeled
/// stream bandwidth (`layout::cost::HOST_STREAM_BYTES_PER_SEC`) yields the
/// bytes-per-bucket target (`layout::cost::exchange_bucket_bytes`).  Sized
/// so one bucket amortizes the exchange's rendezvous overhead (~µs of
/// barrier wake-ups) by an order of magnitude while staying small enough
/// that several buckets fit inside one backward pass — the overlap window.
pub const EXCHANGE_BUCKET_TARGET_NS: usize = 50_000;

/// Floor on the bytes-per-bucket target: below this, rendezvous overhead
/// dominates the wire time and splitting buys nothing — tiny models
/// collapse to a single bucket (which degrades gracefully to the serial
/// exchange, just on the communicator thread).
pub const EXCHANGE_BUCKET_MIN_BYTES: usize = 16 * 1024;

const _: () = assert!(EXCHANGE_BUCKET_TARGET_NS > 0 && EXCHANGE_BUCKET_MIN_BYTES > 0);

/// The HostCpu tiling decision for one (M,K)x(K,N) GEMM — the CPU
/// counterpart of [`MatmulPlan`], except these tiles are not a cost model:
/// `runtime::kernel::Gemm` runs exactly what this rule chooses.
///
/// * `mr` x `nr` — the register micro-tile (panel heights of packed A / B).
///   These are NOT a per-shape degree of freedom: the engine's micro-kernel
///   is compiled at [`CPU_MR`] x [`CPU_NR`] (and `run_packed` asserts the
///   rule matches), so the fields exist to let planning/inspection code read
///   the executed tile, not to vary it — changing the micro-tile means
///   changing the constants (which re-specializes the kernel), not the rule;
/// * `nc_cols` — B columns kept cache-resident per pass (multiple of `nr`),
///   sized so the packed block fits [`CPU_CACHE_BUDGET_BYTES`];
/// * `mc_rows` — A rows (multiple of `mr`) a worker streams against one
///   resident B block before moving to the next row block, sized so the
///   packed A block fits [`CPU_A_BLOCK_BUDGET_BYTES`] (shape-aware: small-m
///   GEMMs such as batch-tail and FID-projection shapes keep full height);
/// * `lane` / `k_chains` — which micro-kernel runs and how many independent
///   K accumulation chains it uses (`Exact` ⇒ 1).  For the exact lane K is
///   never split: bit-exact parity with the naive oracle requires each
///   output element to accumulate k ascending in one chain, so the K stream
///   stays register-resident per micro-tile (the CPU analog of streaming
///   the full K through the systolic array).  The fast lane splits K into
///   [`CPU_SIMD_KU`] fixed chains — deterministic, but not oracle-bit-equal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuTileRule {
    pub mr: usize,
    pub nr: usize,
    pub nc_cols: usize,
    pub mc_rows: usize,
    pub k_chains: usize,
    pub lane: KernelLane,
}

impl CpuTileRule {
    /// Exact-lane tiles (the default engine configuration).
    pub fn for_shape(m: usize, k: usize, n: usize) -> CpuTileRule {
        Self::for_shape_lane(KernelLane::Exact, m, k, n)
    }

    /// Per-lane tiling decision — the ONLY place lane micro-tile shapes,
    /// K-chain depth and cache blocking are chosen; kernels assert against
    /// this rule and never decide blocking themselves.
    pub fn for_shape_lane(lane: KernelLane, m: usize, k: usize, n: usize) -> CpuTileRule {
        let (mr, nr, k_chains) = match lane {
            KernelLane::Exact => (CPU_MR, CPU_NR, 1),
            KernelLane::Simd => (CPU_SIMD_MR, CPU_SIMD_NR, CPU_SIMD_KU),
        };
        let np = round_up(n.max(1), nr);
        // B block bytes = nc_cols * k * 4; keep it under the cache budget.
        let fit = if k == 0 { np } else { CPU_CACHE_BUDGET_BYTES / (4 * k) };
        let nc_cols = (fit / nr * nr).clamp(nr, np);
        // A row block bytes = mc_rows * k * 4; full height when it fits.
        let mp = round_up(m.max(1), mr);
        let afit = if k == 0 { mp } else { CPU_A_BLOCK_BUDGET_BYTES / (4 * k) };
        let mc_rows = (afit / mr * mr).clamp(mr, mp);
        CpuTileRule { mr, nr, nc_cols, mc_rows, k_chains, lane }
    }

    /// Worker threads worth spawning for this GEMM: never more than the
    /// row-panel count, and exactly one when the matmul is too small to
    /// amortize a scoped-thread spawn (~tens of microseconds).
    pub fn effective_threads(&self, requested: usize, m: usize, k: usize, n: usize) -> usize {
        let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
        if flops < 1 << 17 {
            return 1;
        }
        requested.clamp(1, m.div_ceil(self.mr))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileRule {
    /// Required multiple on the second-minor (row/sublane) dimension.
    pub row: usize,
    /// Required multiple on the minor (column/lane) dimension.
    pub col: usize,
}

impl Accelerator {
    /// Preferred tile multiples for the given element width (bytes).
    pub fn tile_rule(&self, elem_bytes: usize) -> TileRule {
        match self {
            Accelerator::TpuV3 => TileRule { row: 8, col: 128 },
            Accelerator::V100 => TileRule { row: 8, col: 8 },
            Accelerator::A100 => {
                if elem_bytes <= 2 {
                    TileRule { row: 64, col: 64 }
                } else {
                    TileRule { row: 32, col: 32 }
                }
            }
            Accelerator::HostCpu => TileRule { row: CPU_MR, col: CPU_NR },
        }
    }

    /// Peak matmul throughput in FLOP/s (dense, mixed precision).
    /// TPU v3: 123 TFLOP/s bf16 per chip => 61.5 per core ("worker").
    /// V100: 125 TFLOP/s fp16 tensor core. A100: 312 TFLOP/s.
    /// HostCpu: derived from the exact lane's issue width — see
    /// [`host_peak_flops`] for the per-lane derivation.
    pub fn peak_flops(&self) -> f64 {
        match self {
            Accelerator::TpuV3 => 61.5e12,
            Accelerator::V100 => 125.0e12 / 8.0 * 8.0, // per-GPU
            Accelerator::A100 => 312.0e12,
            Accelerator::HostCpu => host_peak_flops(KernelLane::Exact),
        }
    }
}

/// Nominal host clock for the cost model.  Plan code is lint-banned from
/// timing calls (kernel purity), so the model uses a fixed documented
/// frequency; absolute numbers are ballpark, RATIOS between lanes are
/// structural (the clock and core count cancel) and are what the planner
/// and the regression tests rely on.
const HOST_CLOCK_HZ: f64 = 3.0e9;

/// Per-lane host peak in FLOP/s, derived from issue width instead of the
/// former fictional `1.0e11` constant:
///
/// * `Exact` — the scalar-semantics kernel autovectorizes to one vector
///   multiply + one vector add per cycle (two issue ports, no FMA):
///   `2 * CPU_SIMD_LANES` FLOP/cycle/core.
/// * `Simd` — two fused-multiply-add issues per cycle, each counting
///   2 FLOPs per lane: `2 * 2 * CPU_SIMD_LANES` FLOP/cycle/core.
///
/// The Simd:Exact ratio is therefore exactly 2.0 on every arch — pinned by
/// a regression test so the cost model can never silently drift back to a
/// fictional machine.
pub fn host_peak_flops(lane: KernelLane) -> f64 {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64;
    let flops_per_cycle = match lane {
        KernelLane::Exact => (2 * CPU_SIMD_LANES) as f64,
        KernelLane::Simd => (2 * 2 * CPU_SIMD_LANES) as f64,
    };
    HOST_CLOCK_HZ * flops_per_cycle * cores
}

pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// A planned (M,K)x(K,N) matmul on a tiled accelerator — mirror of the
/// python `MatmulPlan`.
#[derive(Debug, Clone, Copy)]
pub struct MatmulPlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub mp: usize,
    pub kp: usize,
    pub np: usize,
    pub bm: usize,
    pub bk: usize,
    pub bn: usize,
    pub elem_bytes: usize,
}

impl MatmulPlan {
    /// Plan on TPU v3 rules with VMEM-budgeted blocks (python parity).
    pub fn tpu(m: usize, k: usize, n: usize, elem_bytes: usize) -> MatmulPlan {
        Self::for_accel(Accelerator::TpuV3, m, k, n, elem_bytes)
    }

    pub fn for_accel(acc: Accelerator, m: usize, k: usize, n: usize, elem_bytes: usize) -> MatmulPlan {
        let rule = acc.tile_rule(elem_bytes);
        let (sublane, lane) = (rule.row, rule.col);
        let mp = round_up(m.max(1), sublane);
        let kp = round_up(k.max(1), lane);
        let np = round_up(n.max(1), lane);
        // Mirror of the python planner (§Perf iteration 1: tall M-blocks).
        let bm = divisor_block(mp, 1024, sublane);
        let bn = divisor_block(np, 256, lane);
        let mut pref_k = 2048;
        loop {
            let bk = divisor_block(kp, pref_k, lane);
            let plan = MatmulPlan { m, k, n, mp, kp, np, bm, bk, bn, elem_bytes };
            if plan.vmem_bytes() <= VMEM_BUDGET_BYTES || bk == lane {
                return plan;
            }
            pref_k = bk - lane;
        }
    }

    pub fn grid(&self) -> (usize, usize, usize) {
        (self.mp / self.bm, self.np / self.bn, self.kp / self.bk)
    }

    /// VMEM residency of one grid step (x block + w block + f32 acc block).
    pub fn vmem_bytes(&self) -> usize {
        self.bm * self.bk * self.elem_bytes + self.bk * self.bn * self.elem_bytes
            + self.bm * self.bn * 4
    }

    pub fn real_flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }

    pub fn padded_flops(&self) -> f64 {
        2.0 * self.mp as f64 * self.kp as f64 * self.np as f64
    }

    /// Fraction of MXU work that is useful — Fig. 10's utilization driver.
    pub fn mxu_occupancy(&self) -> f64 {
        self.real_flops() / self.padded_flops()
    }

    /// Systolic-array fill factor: a matmul with fewer than MXU_DIM rows
    /// cannot keep the 128-deep systolic pipeline full, so throughput drops
    /// ~proportionally.  This is the "per-worker batch of 1 under-utilizes
    /// the TPU" effect behind Fig. 8's strong-scaling saturation.
    pub fn systolic_fill(&self) -> f64 {
        let row_fill = (self.mp as f64 / MXU_DIM as f64).min(1.0);
        // Pipeline fill/drain (~MXU_DIM cycles) amortized over the K stream.
        let k_amort = self.kp as f64 / (self.kp as f64 + MXU_DIM as f64);
        row_fill * k_amort
    }

    /// Wall-clock MXU cost in FLOP-equivalents: padded work slowed by
    /// pipeline under-fill.
    pub fn mxu_cost_flops(&self) -> f64 {
        self.padded_flops() / self.systolic_fill()
    }

    pub fn padding_waste(&self) -> f64 {
        1.0 - self.mxu_occupancy()
    }

    /// Bytes moved HBM->VMEM assuming each padded operand + result is
    /// streamed once (lower bound; double-buffering hides latency, not
    /// volume).
    pub fn hbm_bytes(&self) -> f64 {
        (self.mp * self.kp + self.kp * self.np) as f64 * self.elem_bytes as f64
            + (self.mp * self.np) as f64 * 4.0
    }

    /// Plan one GEMM for a host lane: padding follows that lane's
    /// [`CpuTileRule`] (m to `mr`, n to `nr`; K is never padded on the host
    /// — packed panels are exactly k deep), blocks follow `mc_rows` /
    /// `nc_cols`, so `mxu_occupancy`/`padded_flops` report the padding the
    /// engine actually executes per lane.  `layout::cost` builds its
    /// per-lane estimates on top of this.
    pub fn for_host_lane(lane: KernelLane, m: usize, k: usize, n: usize) -> MatmulPlan {
        let r = CpuTileRule::for_shape_lane(lane, m, k, n);
        let mp = round_up(m.max(1), r.mr);
        let kp = k.max(1);
        let np = round_up(n.max(1), r.nr);
        MatmulPlan {
            m,
            k,
            n,
            mp,
            kp,
            np,
            bm: r.mc_rows.min(mp),
            bk: kp,
            bn: r.nc_cols.min(np),
            elem_bytes: 4,
        }
    }
}

// ---------------------------------------------------------------------------
// Memory planning — the arena layer of the layout transformer
// ---------------------------------------------------------------------------

/// First-fit interval allocator over an abstract f32 arena.  This is the ONE
/// placement policy for step-scratch memory: `MemoryPlan::assign` runs it
/// over a buffer-request trace at plan time, and `runtime::workspace` runs
/// the same allocator live, so planned offsets and executed offsets agree by
/// construction (PR-3's "the planner's tiles are the tiles the engine runs",
/// applied to bytes).
///
/// All operations are heap-free once `with_capacity` has reserved the free
/// list (splits and coalesced releases never exceed one interval per
/// outstanding buffer plus one).
#[derive(Debug, Clone)]
pub struct IntervalAlloc {
    /// Free intervals (offset, len), sorted by offset, always coalesced.
    free: Vec<(usize, usize)>,
    total: usize,
}

impl IntervalAlloc {
    pub fn new(total: usize) -> IntervalAlloc {
        IntervalAlloc::with_capacity(total, 64)
    }

    pub fn with_capacity(total: usize, cap: usize) -> IntervalAlloc {
        let mut free = Vec::with_capacity(cap.max(4));
        if total > 0 {
            free.push((0, total));
        }
        IntervalAlloc { free, total }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Drop all checkouts and make the whole (possibly resized) arena free.
    /// Keeps the free list's capacity — no allocation in steady state.
    pub fn reset(&mut self, total: usize) {
        self.free.clear();
        if total > 0 {
            self.free.push((0, total));
        }
        self.total = total;
    }

    /// First-fit: the lowest-offset free interval that holds `len`.
    /// Deterministic in the request/release sequence alone.
    pub fn alloc(&mut self, len: usize) -> Option<usize> {
        if len == 0 {
            return Some(0);
        }
        for i in 0..self.free.len() {
            let (off, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + len, flen - len);
                }
                return Some(off);
            }
        }
        None
    }

    /// Return an interval, coalescing with free neighbours.
    pub fn release(&mut self, off: usize, len: usize) {
        if len == 0 {
            return;
        }
        debug_assert!(off + len <= self.total, "release past arena end");
        let i = self.free.partition_point(|&(o, _)| o < off);
        // Double-free / bad-handle detection: the released range must be
        // disjoint from both free neighbours, or some bytes were already
        // free — the checkout discipline (each interval out at most once)
        // has been violated.
        debug_assert!(
            i >= self.free.len() || off + len <= self.free[i].0,
            "release [{off}..{}) overlaps free interval at {}",
            off + len,
            self.free[i].0
        );
        debug_assert!(
            i == 0 || self.free[i - 1].0 + self.free[i - 1].1 <= off,
            "release [{off}..{}) overlaps free interval at {}",
            off + len,
            self.free[i - 1].0
        );
        self.free.insert(i, (off, len));
        if i + 1 < self.free.len() && self.free[i].0 + self.free[i].1 == self.free[i + 1].0 {
            let add = self.free[i + 1].1;
            self.free[i].1 += add;
            self.free.remove(i + 1);
        }
        if i > 0 && self.free[i - 1].0 + self.free[i - 1].1 == self.free[i].0 {
            let add = self.free[i].1;
            self.free[i - 1].1 += add;
            self.free.remove(i);
        }
    }
}

/// One buffer request in a step's memory trace: `len` f32 values live over
/// the half-open-free event range `[start, end]` (event indices along the
/// walk of the arch array — acquire at `start`, release after `end`).
#[derive(Debug, Clone)]
pub struct BufReq {
    pub name: String,
    pub len: usize,
    pub start: usize,
    pub end: usize,
}

/// A planned buffer: the request plus its assigned arena offset.
#[derive(Debug, Clone)]
pub struct PlannedBuf {
    pub name: String,
    pub len: usize,
    pub start: usize,
    pub end: usize,
    pub offset: usize,
}

impl PlannedBuf {
    fn overlaps_time(&self, other: &PlannedBuf) -> bool {
        self.start <= other.end && other.start <= self.end
    }

    fn overlaps_bytes(&self, other: &PlannedBuf) -> bool {
        self.len > 0
            && other.len > 0
            && self.offset < other.offset + other.len
            && other.offset < self.offset + self.len
    }
}

/// The planned step arena: every intermediate of one training step placed at
/// a fixed offset, with buffers whose live ranges do not overlap sharing
/// bytes.  Built once per (model, batch, thread-count) — see
/// `runtime::workspace::step_memory_plan`, which walks the same `arch` array
/// the backend executes and feeds the trace through [`MemoryPlan::assign`].
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    pub bufs: Vec<PlannedBuf>,
    /// Arena size in f32 values (max watermark of the placement).
    pub total: usize,
    /// Replica whose thread owns the backing slab (first-touch locality):
    /// stamped by `runtime::workspace::step_memory_plan` from the calling
    /// thread's replica binding, `None` for unbound (single-replica) plans.
    /// Checkouts against an owned plan must never migrate threads.
    pub owner: Option<usize>,
}

impl MemoryPlan {
    /// Place a request trace with first-fit reuse across non-overlapping
    /// live ranges.  Requests are processed in ascending `start` (ties in
    /// trace order); before each acquisition every earlier buffer whose
    /// `end` precedes the new `start` is released (ascending (end, index)
    /// order).  Pure function of the trace — stable offsets across runs.
    pub fn assign(reqs: Vec<BufReq>) -> MemoryPlan {
        // Effectively-unbounded arena; the high-water mark becomes `total`.
        const INF: usize = usize::MAX / 4;
        let mut order: Vec<usize> = (0..reqs.len()).collect();
        order.sort_by_key(|&i| (reqs[i].start, i));

        let mut alloc = IntervalAlloc::with_capacity(INF, reqs.len() * 2 + 4);
        let mut bufs: Vec<Option<PlannedBuf>> = (0..reqs.len()).map(|_| None).collect();
        // (end, index) of live buffers, kept sorted ascending.
        let mut live: Vec<(usize, usize)> = Vec::with_capacity(reqs.len());
        let mut total = 0usize;

        for &i in &order {
            let r = &reqs[i];
            // Release everything that died before this acquisition.
            while let Some(&(end, j)) = live.first() {
                if end >= r.start {
                    break;
                }
                let b = bufs[j].as_ref().expect("released buf was placed");
                alloc.release(b.offset, b.len);
                live.remove(0);
            }
            let offset = alloc.alloc(r.len).expect("unbounded arena");
            total = total.max(offset + r.len);
            let pos = live.partition_point(|&(e, j)| (e, j) < (r.end, i));
            live.insert(pos, (r.end, i));
            bufs[i] = Some(PlannedBuf {
                name: r.name.clone(),
                len: r.len,
                start: r.start,
                end: r.end,
                offset,
            });
        }
        MemoryPlan {
            bufs: bufs.into_iter().map(|b| b.expect("every request placed")).collect(),
            total,
            owner: None,
        }
    }

    /// Planner invariant: two buffers alive at the same time never share
    /// bytes.  O(n^2) — a plan-time/test-time check, not a hot path.
    pub fn check_no_overlap(&self) -> Result<(), String> {
        for (i, a) in self.bufs.iter().enumerate() {
            for b in &self.bufs[i + 1..] {
                if a.overlaps_time(b) && a.overlaps_bytes(b) {
                    return Err(format!(
                        "'{}' [{}..{}) and '{}' [{}..{}) are simultaneously live \
                         and share bytes",
                        a.name,
                        a.offset,
                        a.offset + a.len,
                        b.name,
                        b.offset,
                        b.offset + b.len
                    ));
                }
            }
        }
        Ok(())
    }

    /// How many bytes the plan reuses: sum of buffer sizes minus the arena
    /// size (0 = no sharing).
    pub fn reused(&self) -> usize {
        self.bufs.iter().map(|b| b.len).sum::<usize>().saturating_sub(self.total)
    }
}

/// Largest multiple of `tile` that divides `dim` and is <= pref.
fn divisor_block(dim: usize, pref: usize, tile: usize) -> usize {
    debug_assert_eq!(dim % tile, 0);
    let mut best = tile;
    let mut b = tile;
    while b <= dim.min(pref) {
        if dim % b == 0 {
            best = b;
        }
        b += tile;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gens};

    #[test]
    fn paper_example_100x100_wastes_39pct() {
        // Paper §4.2: "a matrix of shape [100, 100] will need 6384 zeros
        // padded to run on a 128x128 matrix unit, which wastes 39%".
        let padded = round_up(100, 128) * round_up(100, 128) - 100 * 100;
        assert_eq!(padded, 6384);
        let waste = padded as f64 / (128.0 * 128.0);
        assert!((waste - 0.39).abs() < 0.01, "{waste}");
    }

    #[test]
    fn aligned_shapes_full_occupancy() {
        let p = MatmulPlan::tpu(256, 512, 128, 4);
        assert_eq!(p.mxu_occupancy(), 1.0);
        assert_eq!(p.grid().0 * p.bm, 256);
    }

    #[test]
    fn plan_respects_vmem_budget() {
        let p = MatmulPlan::tpu(4096, 65536, 4096, 4);
        assert!(p.vmem_bytes() <= VMEM_BUDGET_BYTES || p.bk == 128);
    }

    #[test]
    fn accelerator_tile_rules() {
        assert_eq!(Accelerator::TpuV3.tile_rule(4), TileRule { row: 8, col: 128 });
        assert_eq!(Accelerator::A100.tile_rule(2), TileRule { row: 64, col: 64 });
        assert_eq!(Accelerator::A100.tile_rule(4), TileRule { row: 32, col: 32 });
        assert_eq!(Accelerator::V100.tile_rule(2), TileRule { row: 8, col: 8 });
    }

    #[test]
    fn prop_plan_invariants() {
        forall(
            gens::vec(gens::usize_in(1..2000), 3..4),
            |dims| {
                let (m, k, n) = (dims[0], dims[1], dims[2]);
                let p = MatmulPlan::tpu(m, k, n, 4);
                p.mp % 8 == 0
                    && p.kp % 128 == 0
                    && p.np % 128 == 0
                    && p.mp >= m
                    && p.kp >= k
                    && p.np >= n
                    && p.mp % p.bm == 0
                    && p.kp % p.bk == 0
                    && p.np % p.bn == 0
                    && p.mxu_occupancy() > 0.0
                    && p.mxu_occupancy() <= 1.0
                    && (p.vmem_bytes() <= VMEM_BUDGET_BYTES || p.bk == 128)
            },
        );
    }

    #[test]
    fn host_cpu_tile_rule_matches_micro_kernel_constants() {
        assert_eq!(
            Accelerator::HostCpu.tile_rule(4),
            TileRule { row: CPU_MR, col: CPU_NR }
        );
        // HostCpu plans flow through the same MatmulPlan machinery.
        let p = MatmulPlan::for_accel(Accelerator::HostCpu, 100, 100, 100, 4);
        assert_eq!(p.mp % CPU_MR, 0);
        assert_eq!(p.np % CPU_NR, 0);
        assert!(p.mxu_occupancy() > 0.9, "{}", p.mxu_occupancy());
    }

    #[test]
    fn prop_cpu_tile_rule_invariants() {
        forall(gens::vec(gens::usize_in(1..5000), 3..4), |dims| {
            let (m, k, n) = (dims[0], dims[1], dims[2]);
            let r = CpuTileRule::for_shape(m, k, n);
            let block_fits = r.nc_cols * k * 4 <= CPU_CACHE_BUDGET_BYTES
                || r.nc_cols == CPU_NR
                || r.nc_cols >= round_up(n, CPU_NR);
            r.mr == CPU_MR
                && r.nr == CPU_NR
                && r.nc_cols % CPU_NR == 0
                && r.nc_cols >= CPU_NR
                && block_fits
                && r.effective_threads(64, m, k, n) <= m.div_ceil(CPU_MR)
                && r.effective_threads(0, m, k, n) >= 1
                && r.effective_threads(8, 4, 4, 4) == 1 // tiny matmul: no spawn
        });
    }

    #[test]
    fn prop_simd_lane_tiles_widen_nr_and_deepen_k_chain() {
        forall(gens::vec(gens::usize_in(1..5000), 3..4), |dims| {
            let (m, k, n) = (dims[0], dims[1], dims[2]);
            let e = CpuTileRule::for_shape_lane(KernelLane::Exact, m, k, n);
            let s = CpuTileRule::for_shape_lane(KernelLane::Simd, m, k, n);
            e == CpuTileRule::for_shape(m, k, n)
                && e.lane == KernelLane::Exact
                && e.k_chains == 1
                && s.lane == KernelLane::Simd
                && (s.mr, s.nr, s.k_chains) == (CPU_SIMD_MR, CPU_SIMD_NR, CPU_SIMD_KU)
                && s.mr == e.mr // shared A-panel layout across lanes
                && s.nr >= e.nr
                && s.nr <= CPU_NR_ANY
                && s.nc_cols % s.nr == 0
                && s.mc_rows % s.mr == 0
                && s.mc_rows >= s.mr
                && (s.mc_rows * k * 4 <= CPU_A_BLOCK_BUDGET_BYTES
                    || s.mc_rows == s.mr
                    || s.mc_rows >= round_up(m, s.mr))
        });
    }

    #[test]
    fn row_blocking_is_shape_aware_at_dcgan32_shapes() {
        // dcgan32 D conv0 im2col GEMM at batch 64: m = 64*16*16, k = 3*4*4.
        let big = CpuTileRule::for_shape(64 * 16 * 16, 48, 64);
        assert_eq!(big.mc_rows, CPU_A_BLOCK_BUDGET_BYTES / (4 * 48) / CPU_MR * CPU_MR);
        assert!(big.mc_rows < 64 * 16 * 16, "huge-m A blocks are capped");
        // Batch-tail shape (m = 8): the whole A block fits — full height.
        let tail = CpuTileRule::for_shape(8, 48, 64);
        assert_eq!(tail.mc_rows, round_up(8, CPU_MR), "small m keeps full-height panels");
        // FID-projection shape: small m but deep K — block capped by budget.
        let fid = CpuTileRule::for_shape(64, 3 * 32 * 32, 2048);
        assert_eq!(fid.mc_rows, CPU_A_BLOCK_BUDGET_BYTES / (4 * 3072) / CPU_MR * CPU_MR);
        assert!(fid.mc_rows >= CPU_MR && fid.mc_rows < 64);
        // The m argument is no longer ignored: same k/n, different m.
        assert_ne!(
            CpuTileRule::for_shape(8, 48, 64).mc_rows,
            CpuTileRule::for_shape(64 * 16 * 16, 48, 64).mc_rows
        );
    }

    #[test]
    fn host_peak_flops_lane_ratio_pinned() {
        let exact = host_peak_flops(KernelLane::Exact);
        let simd = host_peak_flops(KernelLane::Simd);
        assert!(exact > 0.0 && exact.is_finite());
        // FMA doubles the per-issue FLOPs — structural, arch-independent.
        assert_eq!(simd / exact, 2.0, "lane peak ratio drifted");
        // HostCpu's Accelerator peak is the exact (default) lane, no longer
        // the fictional 1.0e11 placeholder.
        assert_eq!(Accelerator::HostCpu.peak_flops(), exact);
    }

    #[test]
    fn host_lane_plan_reports_lane_padding() {
        for lane in [KernelLane::Exact, KernelLane::Simd] {
            let r = CpuTileRule::for_shape_lane(lane, 100, 50, 100);
            let p = MatmulPlan::for_host_lane(lane, 100, 50, 100);
            assert_eq!(p.kp, 50, "host K is never padded");
            assert_eq!(p.mp % r.mr, 0);
            assert_eq!(p.np % r.nr, 0);
            assert!(p.mxu_occupancy() > 0.0 && p.mxu_occupancy() <= 1.0);
        }
        // A 1-column GEMM pads to the lane width: the wide lane wastes more.
        let e = MatmulPlan::for_host_lane(KernelLane::Exact, 64, 64, 1);
        let s = MatmulPlan::for_host_lane(KernelLane::Simd, 64, 64, 1);
        assert!(s.padded_flops() >= e.padded_flops());
        assert!(s.mxu_occupancy() <= e.mxu_occupancy());
    }

    fn req(name: &str, len: usize, start: usize, end: usize) -> BufReq {
        BufReq { name: name.into(), len, start, end }
    }

    #[test]
    fn interval_alloc_first_fit_reuses_and_coalesces() {
        let mut a = IntervalAlloc::new(100);
        let x = a.alloc(30).unwrap();
        let y = a.alloc(30).unwrap();
        let z = a.alloc(30).unwrap();
        assert_eq!((x, y, z), (0, 30, 60));
        assert!(a.alloc(20).is_none(), "only 10 of 100 left");
        a.release(30, 30); // free the middle
        assert_eq!(a.alloc(30).unwrap(), 30, "first-fit reuses the freed hole");
        a.release(0, 30);
        a.release(30, 30);
        a.release(60, 30);
        // Fully coalesced: one 100-wide interval serves a 95.
        assert_eq!(a.alloc(95).unwrap(), 0);
    }

    #[test]
    fn interval_alloc_rejects_when_full() {
        let mut a = IntervalAlloc::new(10);
        assert_eq!(a.alloc(10), Some(0));
        assert_eq!(a.alloc(1), None);
        a.reset(10);
        assert_eq!(a.alloc(10), Some(0));
    }

    #[test]
    fn memory_plan_no_overlap_and_reuse() {
        // a and b overlap in time; c starts after a dies, so it may (and
        // with first-fit, will) reuse a's bytes.
        let plan = MemoryPlan::assign(vec![
            req("a", 64, 0, 2),
            req("b", 32, 1, 5),
            req("c", 64, 3, 6),
        ]);
        plan.check_no_overlap().unwrap();
        let a = &plan.bufs[0];
        let c = &plan.bufs[2];
        assert_eq!(c.offset, a.offset, "disjoint live ranges share the slab");
        assert_eq!(plan.total, 96, "arena is peak live, not sum of sizes");
        assert_eq!(plan.reused(), 64);
    }

    #[test]
    fn memory_plan_offsets_are_stable_across_runs() {
        let trace = || {
            vec![
                req("x0", 128, 0, 9),
                req("pre0", 256, 1, 8),
                req("im2col", 512, 1, 1),
                req("pre1", 64, 2, 7),
                req("grad1", 64, 7, 8),
                req("grad0", 256, 8, 9),
            ]
        };
        let p1 = MemoryPlan::assign(trace());
        let p2 = MemoryPlan::assign(trace());
        for (a, b) in p1.bufs.iter().zip(&p2.bufs) {
            assert_eq!((a.offset, a.len), (b.offset, b.len), "{}", a.name);
        }
        p1.check_no_overlap().unwrap();
        assert_eq!(p1.total, p2.total);
    }

    #[test]
    fn prop_memory_plan_never_overlaps() {
        forall(gens::vec(gens::usize_in(1..64), 12..13), |dims| {
            let reqs: Vec<BufReq> = dims
                .chunks(3)
                .enumerate()
                .map(|(i, c)| {
                    let (s, e) = (c[1].min(c[2]), c[1].max(c[2]));
                    req(&format!("b{i}"), c[0], s, e)
                })
                .collect();
            MemoryPlan::assign(reqs).check_no_overlap().is_ok()
        });
    }

    #[test]
    fn prop_occupancy_monotone_in_alignment() {
        // Aligning a dim can only improve (or keep) occupancy.
        forall(gens::usize_in(1..512), |&m| {
            let unaligned = MatmulPlan::tpu(m, 300, 300, 4);
            let aligned = MatmulPlan::tpu(round_up(m, 8), 300, 300, 4);
            aligned.mxu_occupancy() >= unaligned.mxu_occupancy() - 1e-12
        });
    }
}
