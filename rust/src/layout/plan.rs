//! Padding/tiling plans per accelerator.
//!
//! Paper §3.3: "Nvidia A100 GPUs prefer half-precision data in multiples of
//! 64, and single-precision data in multiples of 32, while previous
//! generations prefer multiples of 8. For TPU, the preferred data layout
//! should have a multiple of 128 on the lane dimension and 8 on the sublane
//! dimension."

/// TPU v3 per-core VMEM is 16 MiB; plan against half for double-buffering
/// (matches the python planner).
pub const VMEM_BUDGET_BYTES: usize = 8 * 1024 * 1024;

/// MXU systolic array dimension (TPU v2/v3: 128x128).
pub const MXU_DIM: usize = 128;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accelerator {
    /// TPU v2/v3: (sublane=8, lane=128).
    TpuV3,
    /// V100: tensor-core era, multiples of 8.
    V100,
    /// A100: fp16 multiples of 64, fp32 multiples of 32.
    A100,
    /// The host CPU running the `RefCpuBackend` — the one accelerator this
    /// planner does not merely *model* but actually *drives*: the tiles it
    /// picks here are the register blocks `runtime::kernel::Gemm` executes
    /// (see [`CpuTileRule`]).
    HostCpu,
}

/// Register micro-tile of the CPU GEMM engine: MR rows of A are held
/// against NR columns of B in an MR x NR f32 accumulator block (32 scalars
/// — comfortably register-resident; NR=8 matches one 256-bit f32 vector so
/// the inner loop autovectorizes).
pub const CPU_MR: usize = 4;
pub const CPU_NR: usize = 8;

/// Cache share the packed B block may occupy while A panels stream past it
/// — the CPU analog of the VMEM budget above (a conservative L2 slice).
pub const CPU_CACHE_BUDGET_BYTES: usize = 192 * 1024;

/// The HostCpu tiling decision for one (M,K)x(K,N) GEMM — the CPU
/// counterpart of [`MatmulPlan`], except these tiles are not a cost model:
/// `runtime::kernel::Gemm` runs exactly what this rule chooses.
///
/// * `mr` x `nr` — the register micro-tile (panel heights of packed A / B).
///   These are NOT a per-shape degree of freedom: the engine's micro-kernel
///   is compiled at [`CPU_MR`] x [`CPU_NR`] (and `run_packed` asserts the
///   rule matches), so the fields exist to let planning/inspection code read
///   the executed tile, not to vary it — changing the micro-tile means
///   changing the constants (which re-specializes the kernel), not the rule;
/// * `nc_cols` — B columns kept cache-resident per pass (multiple of `nr`),
///   sized so the packed block fits [`CPU_CACHE_BUDGET_BYTES`];
/// * K is never split: bit-exact parity with the naive oracle requires each
///   output element to accumulate k ascending in one chain, so the K stream
///   stays register-resident per micro-tile (the CPU analog of streaming
///   the full K through the systolic array).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuTileRule {
    pub mr: usize,
    pub nr: usize,
    pub nc_cols: usize,
}

impl CpuTileRule {
    pub fn for_shape(_m: usize, k: usize, n: usize) -> CpuTileRule {
        let np = round_up(n.max(1), CPU_NR);
        // B block bytes = nc_cols * k * 4; keep it under the cache budget.
        let fit = if k == 0 { np } else { CPU_CACHE_BUDGET_BYTES / (4 * k) };
        let nc_cols = (fit / CPU_NR * CPU_NR).clamp(CPU_NR, np);
        CpuTileRule { mr: CPU_MR, nr: CPU_NR, nc_cols }
    }

    /// Worker threads worth spawning for this GEMM: never more than the
    /// row-panel count, and exactly one when the matmul is too small to
    /// amortize a scoped-thread spawn (~tens of microseconds).
    pub fn effective_threads(&self, requested: usize, m: usize, k: usize, n: usize) -> usize {
        let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
        if flops < 1 << 17 {
            return 1;
        }
        requested.clamp(1, m.div_ceil(self.mr))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileRule {
    /// Required multiple on the second-minor (row/sublane) dimension.
    pub row: usize,
    /// Required multiple on the minor (column/lane) dimension.
    pub col: usize,
}

impl Accelerator {
    /// Preferred tile multiples for the given element width (bytes).
    pub fn tile_rule(&self, elem_bytes: usize) -> TileRule {
        match self {
            Accelerator::TpuV3 => TileRule { row: 8, col: 128 },
            Accelerator::V100 => TileRule { row: 8, col: 8 },
            Accelerator::A100 => {
                if elem_bytes <= 2 {
                    TileRule { row: 64, col: 64 }
                } else {
                    TileRule { row: 32, col: 32 }
                }
            }
            Accelerator::HostCpu => TileRule { row: CPU_MR, col: CPU_NR },
        }
    }

    /// Peak matmul throughput in FLOP/s (dense, mixed precision).
    /// TPU v3: 123 TFLOP/s bf16 per chip => 61.5 per core ("worker").
    /// V100: 125 TFLOP/s fp16 tensor core. A100: 312 TFLOP/s.
    pub fn peak_flops(&self) -> f64 {
        match self {
            Accelerator::TpuV3 => 61.5e12,
            Accelerator::V100 => 125.0e12 / 8.0 * 8.0, // per-GPU
            Accelerator::A100 => 312.0e12,
            // Ballpark multi-core f32 SIMD throughput — the ref backend's
            // GEMM engine, not a tensor unit.
            Accelerator::HostCpu => 1.0e11,
        }
    }
}

pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// A planned (M,K)x(K,N) matmul on a tiled accelerator — mirror of the
/// python `MatmulPlan`.
#[derive(Debug, Clone, Copy)]
pub struct MatmulPlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub mp: usize,
    pub kp: usize,
    pub np: usize,
    pub bm: usize,
    pub bk: usize,
    pub bn: usize,
    pub elem_bytes: usize,
}

impl MatmulPlan {
    /// Plan on TPU v3 rules with VMEM-budgeted blocks (python parity).
    pub fn tpu(m: usize, k: usize, n: usize, elem_bytes: usize) -> MatmulPlan {
        Self::for_accel(Accelerator::TpuV3, m, k, n, elem_bytes)
    }

    pub fn for_accel(acc: Accelerator, m: usize, k: usize, n: usize, elem_bytes: usize) -> MatmulPlan {
        let rule = acc.tile_rule(elem_bytes);
        let (sublane, lane) = (rule.row, rule.col);
        let mp = round_up(m.max(1), sublane);
        let kp = round_up(k.max(1), lane);
        let np = round_up(n.max(1), lane);
        // Mirror of the python planner (§Perf iteration 1: tall M-blocks).
        let bm = divisor_block(mp, 1024, sublane);
        let bn = divisor_block(np, 256, lane);
        let mut pref_k = 2048;
        loop {
            let bk = divisor_block(kp, pref_k, lane);
            let plan = MatmulPlan { m, k, n, mp, kp, np, bm, bk, bn, elem_bytes };
            if plan.vmem_bytes() <= VMEM_BUDGET_BYTES || bk == lane {
                return plan;
            }
            pref_k = bk - lane;
        }
    }

    pub fn grid(&self) -> (usize, usize, usize) {
        (self.mp / self.bm, self.np / self.bn, self.kp / self.bk)
    }

    /// VMEM residency of one grid step (x block + w block + f32 acc block).
    pub fn vmem_bytes(&self) -> usize {
        self.bm * self.bk * self.elem_bytes + self.bk * self.bn * self.elem_bytes
            + self.bm * self.bn * 4
    }

    pub fn real_flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }

    pub fn padded_flops(&self) -> f64 {
        2.0 * self.mp as f64 * self.kp as f64 * self.np as f64
    }

    /// Fraction of MXU work that is useful — Fig. 10's utilization driver.
    pub fn mxu_occupancy(&self) -> f64 {
        self.real_flops() / self.padded_flops()
    }

    /// Systolic-array fill factor: a matmul with fewer than MXU_DIM rows
    /// cannot keep the 128-deep systolic pipeline full, so throughput drops
    /// ~proportionally.  This is the "per-worker batch of 1 under-utilizes
    /// the TPU" effect behind Fig. 8's strong-scaling saturation.
    pub fn systolic_fill(&self) -> f64 {
        let row_fill = (self.mp as f64 / MXU_DIM as f64).min(1.0);
        // Pipeline fill/drain (~MXU_DIM cycles) amortized over the K stream.
        let k_amort = self.kp as f64 / (self.kp as f64 + MXU_DIM as f64);
        row_fill * k_amort
    }

    /// Wall-clock MXU cost in FLOP-equivalents: padded work slowed by
    /// pipeline under-fill.
    pub fn mxu_cost_flops(&self) -> f64 {
        self.padded_flops() / self.systolic_fill()
    }

    pub fn padding_waste(&self) -> f64 {
        1.0 - self.mxu_occupancy()
    }

    /// Bytes moved HBM->VMEM assuming each padded operand + result is
    /// streamed once (lower bound; double-buffering hides latency, not
    /// volume).
    pub fn hbm_bytes(&self) -> f64 {
        (self.mp * self.kp + self.kp * self.np) as f64 * self.elem_bytes as f64
            + (self.mp * self.np) as f64 * 4.0
    }
}

/// Largest multiple of `tile` that divides `dim` and is <= pref.
fn divisor_block(dim: usize, pref: usize, tile: usize) -> usize {
    debug_assert_eq!(dim % tile, 0);
    let mut best = tile;
    let mut b = tile;
    while b <= dim.min(pref) {
        if dim % b == 0 {
            best = b;
        }
        b += tile;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gens};

    #[test]
    fn paper_example_100x100_wastes_39pct() {
        // Paper §4.2: "a matrix of shape [100, 100] will need 6384 zeros
        // padded to run on a 128x128 matrix unit, which wastes 39%".
        let padded = round_up(100, 128) * round_up(100, 128) - 100 * 100;
        assert_eq!(padded, 6384);
        let waste = padded as f64 / (128.0 * 128.0);
        assert!((waste - 0.39).abs() < 0.01, "{waste}");
    }

    #[test]
    fn aligned_shapes_full_occupancy() {
        let p = MatmulPlan::tpu(256, 512, 128, 4);
        assert_eq!(p.mxu_occupancy(), 1.0);
        assert_eq!(p.grid().0 * p.bm, 256);
    }

    #[test]
    fn plan_respects_vmem_budget() {
        let p = MatmulPlan::tpu(4096, 65536, 4096, 4);
        assert!(p.vmem_bytes() <= VMEM_BUDGET_BYTES || p.bk == 128);
    }

    #[test]
    fn accelerator_tile_rules() {
        assert_eq!(Accelerator::TpuV3.tile_rule(4), TileRule { row: 8, col: 128 });
        assert_eq!(Accelerator::A100.tile_rule(2), TileRule { row: 64, col: 64 });
        assert_eq!(Accelerator::A100.tile_rule(4), TileRule { row: 32, col: 32 });
        assert_eq!(Accelerator::V100.tile_rule(2), TileRule { row: 8, col: 8 });
    }

    #[test]
    fn prop_plan_invariants() {
        forall(
            gens::vec(gens::usize_in(1..2000), 3..4),
            |dims| {
                let (m, k, n) = (dims[0], dims[1], dims[2]);
                let p = MatmulPlan::tpu(m, k, n, 4);
                p.mp % 8 == 0
                    && p.kp % 128 == 0
                    && p.np % 128 == 0
                    && p.mp >= m
                    && p.kp >= k
                    && p.np >= n
                    && p.mp % p.bm == 0
                    && p.kp % p.bk == 0
                    && p.np % p.bn == 0
                    && p.mxu_occupancy() > 0.0
                    && p.mxu_occupancy() <= 1.0
                    && (p.vmem_bytes() <= VMEM_BUDGET_BYTES || p.bk == 128)
            },
        );
    }

    #[test]
    fn host_cpu_tile_rule_matches_micro_kernel_constants() {
        assert_eq!(
            Accelerator::HostCpu.tile_rule(4),
            TileRule { row: CPU_MR, col: CPU_NR }
        );
        // HostCpu plans flow through the same MatmulPlan machinery.
        let p = MatmulPlan::for_accel(Accelerator::HostCpu, 100, 100, 100, 4);
        assert_eq!(p.mp % CPU_MR, 0);
        assert_eq!(p.np % CPU_NR, 0);
        assert!(p.mxu_occupancy() > 0.9, "{}", p.mxu_occupancy());
    }

    #[test]
    fn prop_cpu_tile_rule_invariants() {
        forall(gens::vec(gens::usize_in(1..5000), 3..4), |dims| {
            let (m, k, n) = (dims[0], dims[1], dims[2]);
            let r = CpuTileRule::for_shape(m, k, n);
            let block_fits = r.nc_cols * k * 4 <= CPU_CACHE_BUDGET_BYTES
                || r.nc_cols == CPU_NR
                || r.nc_cols >= round_up(n, CPU_NR);
            r.mr == CPU_MR
                && r.nr == CPU_NR
                && r.nc_cols % CPU_NR == 0
                && r.nc_cols >= CPU_NR
                && block_fits
                && r.effective_threads(64, m, k, n) <= m.div_ceil(CPU_MR)
                && r.effective_threads(0, m, k, n) >= 1
                && r.effective_threads(8, 4, 4, 4) == 1 // tiny matmul: no spawn
        });
    }

    #[test]
    fn prop_occupancy_monotone_in_alignment() {
        // Aligning a dim can only improve (or keep) occupancy.
        forall(gens::usize_in(1..512), |&m| {
            let unaligned = MatmulPlan::tpu(m, 300, 300, 4);
            let aligned = MatmulPlan::tpu(round_up(m, 8), 300, 300, 4);
            aligned.mxu_occupancy() >= unaligned.mxu_occupancy() - 1e-12
        });
    }
}
