//! `paragan` — leader entrypoint / CLI.
//!
//! ```text
//! paragan train    --model dcgan32 --steps 300 --scheme async --g-opt adabelief --d-opt adam
//! paragan repro    <table1|table2|fig1|fig4|fig6|fig7|fig8|fig9|fig10|fig11|fig13|all>
//! paragan simulate --workers 1024 --per-worker-batch 16 [--framework native_tf]
//! paragan info     [--artifacts artifacts]
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use paragan::cluster::{biggan, simulate, FrameworkProfile, SimConfig};
use paragan::coordinator::{LrScaling, OptimizationPolicy, ScalingConfig};
use paragan::gan::{Estimator, UpdateScheme};
use paragan::metrics::tracker::sparkline;
use paragan::repro;
use paragan::util::cli::Args;
use paragan::util::table::{f2, pct, si, Table};

fn main() {
    let args = Args::from_env(&["help", "verbose"]);
    if let Err(e) = run(&args) {
        eprintln!("error: {e:?}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(args),
        Some("repro") => cmd_repro(args),
        Some("simulate") => cmd_simulate(args),
        Some("info") => cmd_info(args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "paragan — scalable distributed GAN training (SoCC'24 reproduction)\n\n\
         USAGE:\n\
         \x20 paragan train    --model <dcgan32|sngan32|biggan32> --steps N [--scheme sync|async]\n\
         \x20                  [--g-opt OPT] [--d-opt OPT] [--precision fp32|bf16] [--d-ratio N]\n\
         \x20                  [--eval-every N] [--checkpoint-dir DIR] [--artifacts DIR] [--seed N]\n\
         \x20                  [--threads N   GEMM engine workers; default PARAGAN_THREADS or all cores]\n\
         \x20                  [--precision-mode exact|simd  kernel lane; default PARAGAN_KERNEL or exact]\n\
         \x20                  [--replicas N  real multi-replica training (crate::dist)]\n\
         \x20                  [--dist-mode sync|async|mdgan] [--dist-topology tree|ring]\n\
         \x20                  [--staleness-bound N] [--swap-every N]\n\
         \x20                  [--trace FILE  write a Chrome trace-event JSON of the run's phase\n\
         \x20                   spans (chrome://tracing / Perfetto) and print the telemetry report]\n\
         \x20 paragan repro    <table1|table2|fig1|fig4|fig6|fig7|fig8|fig9|fig10|fig11|fig13|all>\n\
         \x20 paragan simulate --workers N [--per-worker-batch N] [--framework paragan|native_tf|studiogan]\n\
         \x20 paragan info     [--artifacts DIR]"
    );
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

/// `--artifacts DIR` wins; otherwise resolve `model` in the executable
/// artifact set (generated reference artifacts on a clean checkout).
/// Unknown models are a hard error, never a silent substitution.
fn resolve_artifacts(args: &Args, model: &str) -> Result<(PathBuf, String)> {
    match args.get("artifacts") {
        Some(d) => Ok((PathBuf::from(d), model.to_string())),
        None => paragan::testkit::artifacts_for(model),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let (dir, model) = resolve_artifacts(args, &args.get_or("model", "dcgan32"))?;
    let steps = args.get_u64("steps", 200);
    let scheme = match args.get_or("scheme", "sync").as_str() {
        "async" => UpdateScheme::Async,
        "sync" => UpdateScheme::Sync,
        other => bail!("unknown scheme '{other}'"),
    };
    let policy = OptimizationPolicy {
        generator: paragan::coordinator::NetPolicy {
            optimizer: args.get_or("g-opt", "adabelief"),
            lr_mult: args.get_f64("g-lr-mult", 1.0),
        },
        discriminator: paragan::coordinator::NetPolicy {
            optimizer: args.get_or("d-opt", "adam"),
            lr_mult: args.get_f64("d-lr-mult", 1.0),
        },
        precision: args.get_or("precision", "fp32"),
        d_steps_per_g: args.get_usize("d-ratio", 1),
    };
    let scaling = ScalingConfig {
        base_lr: args.get_f64("lr", 2e-4),
        warmup_steps: args.get_u64("warmup", 0),
        rule: match args.get_or("lr-scaling", "sqrt").as_str() {
            "linear" => LrScaling::Linear,
            "none" => LrScaling::None,
            _ => LrScaling::Sqrt,
        },
        ..Default::default()
    };

    println!("training {model} for {steps} steps [{scheme:?}] policy: {}", policy.describe());
    let mut est = Estimator::new(&model)
        .artifact_dir(dir)
        .policy(policy)
        .scaling(scaling)
        .scheme(scheme)
        .steps(steps)
        .seed(args.get_u64("seed", 42))
        .eval_every(args.get_u64("eval-every", 0))
        .log_every(args.get_u64("log-every", 25));
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse().context("--threads expects a positive integer")?;
        anyhow::ensure!(n >= 1, "--threads expects a positive integer, got 0");
        est = est.threads(n);
    }
    if let Some(mode) = args.get("precision-mode") {
        est = est.precision_mode(match mode.as_str() {
            "exact" => paragan::layout::plan::KernelLane::Exact,
            "simd" => paragan::layout::plan::KernelLane::Simd,
            other => bail!("unknown precision mode '{other}' (expected exact|simd)"),
        });
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        est = est.checkpoint(dir, args.get_u64("checkpoint-every", 100));
    }

    // --- distributed path: --replicas N [--dist-mode sync|async|mdgan] ---
    let replicas = args.get_usize("replicas", 1);
    if replicas > 1 || args.get("dist-mode").is_some() {
        // An explicit --dist-mode wins; otherwise `--scheme async` carries
        // its intent over to the replicated engine (parameter server)
        // instead of being silently downgraded to lockstep sync.
        let mode = match args.get("dist-mode") {
            Some(m) => paragan::dist::DistMode::parse(m)?,
            None if scheme == UpdateScheme::Async => paragan::dist::DistMode::Async,
            None => paragan::dist::DistMode::Sync,
        };
        est = est
            .replicas(replicas)
            .dist_mode(mode)
            .staleness_bound(args.get_u64("staleness-bound", 2));
        if est.config().checkpoint_dir.is_some() || est.config().eval_every > 0 {
            eprintln!(
                "warning: --checkpoint-dir/--eval-every are not yet honored by \
                 dist runs (final eval only) — see the ROADMAP PR-4 open items"
            );
        }
        {
            let cfg = est.config_mut();
            cfg.dist.topology =
                paragan::dist::Topology::parse(&args.get_or("dist-topology", "tree"))?;
            cfg.dist.swap_every = args.get_u64("swap-every", 8);
        }
        println!(
            "dist: {replicas} replicas, mode {}, topology {:?}, staleness bound {}",
            mode.as_str(),
            est.config().dist.topology,
            est.config().dist.staleness_bound
        );
        let r = est.train_dist()?;
        let res = &r.train;
        println!(
            "\ndone in {:.1}s — {:.2} steps/s/replica-group, {:.2} aggregate replica-steps/s, {:.1} img/s",
            res.wall_secs,
            res.steps_per_sec(),
            r.aggregate_steps_per_sec,
            res.images_per_sec()
        );
        let g: Vec<f64> = res.g_loss.downsample(60).iter().map(|p| p.value).collect();
        let d: Vec<f64> = res.d_loss.downsample(60).iter().map(|p| p.value).collect();
        println!("g_loss {}  (last {:.4})", sparkline(&g), res.g_loss.last().unwrap_or(f64::NAN));
        println!("d_loss {}  (last {:.4})", sparkline(&d), res.d_loss.last().unwrap_or(f64::NAN));
        // "(bound N)" only where --staleness-bound actually governs the
        // number (the async parameter server); mdgan's staleness is the
        // fake-batch age bounded by queue backpressure, sync has none.
        let bound_note = match est.config().dist.mode {
            paragan::dist::DistMode::Async => {
                format!(" (bound {})", est.config().dist.staleness_bound)
            }
            _ => String::new(),
        };
        println!(
            "FID-proxy: {:.2}   mode coverage: {:.2}   mean staleness: {:.2}{}   \
             fake-batch staleness: {:.2}   stale drops: {}   swaps: {}",
            res.final_fid(),
            res.mode_cov.last().unwrap_or(f64::NAN),
            res.mean_staleness,
            bound_note,
            r.mean_fake_staleness,
            r.stale_drops,
            r.swaps
        );
        finish_trace(args)?;
        return Ok(());
    }

    let res = est.train()?;

    println!(
        "\ndone in {:.1}s — {:.2} steps/s, {:.1} img/s",
        res.wall_secs,
        res.steps_per_sec(),
        res.images_per_sec()
    );
    let g: Vec<f64> = res.g_loss.downsample(60).iter().map(|p| p.value).collect();
    let d: Vec<f64> = res.d_loss.downsample(60).iter().map(|p| p.value).collect();
    println!("g_loss {}  (last {:.4})", sparkline(&g), res.g_loss.last().unwrap_or(f64::NAN));
    println!("d_loss {}  (last {:.4})", sparkline(&d), res.d_loss.last().unwrap_or(f64::NAN));
    println!(
        "FID-proxy: {:.2}   mode coverage: {:.2}   mean staleness: {:.2}",
        res.final_fid(),
        res.mode_cov.last().unwrap_or(f64::NAN),
        res.mean_staleness
    );
    finish_trace(args)?;
    Ok(())
}

/// `--trace FILE`: after a train run, print the aggregate telemetry report
/// and export the recorded spans as Chrome trace-event JSON (one lane per
/// replica thread — open in chrome://tracing or Perfetto).
fn finish_trace(args: &Args) -> Result<()> {
    let Some(path) = args.get("trace") else { return Ok(()) };
    println!("{}", paragan::telemetry::report().render());
    paragan::telemetry::write_chrome_trace(std::path::Path::new(&path))
        .with_context(|| format!("writing trace to {path}"))?;
    println!("trace written to {path}");
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let steps = args.get_usize("sim-steps", 200);
    let train_steps = args.get_u64("train-steps", 60);
    let run = |name: &str| -> Result<()> {
        match name {
            "table1" => println!("{}", repro::table1(steps).render()),
            "table2" => println!("{}", repro::table2(steps).0.render()),
            "fig1" => println!("{}", repro::fig1(16, steps).0.render()),
            "fig4" => println!("{}", repro::fig4(16, steps).0.render()),
            "fig7" => println!("{}", repro::fig7(16, steps).0.render()),
            "fig8" => println!("{}", repro::fig8(steps).0.render()),
            "fig9" => {
                println!("{}", repro::fig9(16, steps).0.render());
                // Measured-vs-simulated drift (warn-only): picks up the
                // BENCH_dist.json left by `cargo bench --bench
                // bench_dist_scaling` when one exists.
                if let Some(t) =
                    repro::fig9_crosscheck(std::path::Path::new("BENCH_dist.json"))
                {
                    println!("{}", t.render());
                }
            }
            "fig10" => println!("{}", repro::fig10(16, steps).0.render()),
            "fig11" => println!("{}", repro::fig11(&Default::default()).0.render()),
            "fig6" => {
                let (adir, model) = resolve_artifacts(args, "dcgan32")?;
                let cfg = repro::Fig6Config {
                    artifact_dir: adir,
                    model,
                    steps: train_steps,
                    ..Default::default()
                };
                println!("{}", repro::fig6(&cfg)?.0.render());
            }
            "fig13" => {
                let (adir, model) = resolve_artifacts(args, "sngan32")?;
                let cfg = repro::Fig13Config {
                    artifact_dir: adir,
                    model,
                    steps: train_steps,
                    eval_every: (train_steps / 4).max(1),
                    ..Default::default()
                };
                println!("{}", repro::fig13(&cfg)?.0.render());
            }
            other => bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if which == "all" {
        for name in
            ["table1", "fig1", "fig4", "fig7", "fig8", "fig9", "fig10", "table2", "fig11", "fig6", "fig13"]
        {
            run(name)?;
        }
    } else {
        run(which)?;
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let n = args.get_usize("workers", 128);
    let pwb = args.get_usize("per-worker-batch", 16);
    let mut cfg = SimConfig::tpu_default(biggan(128), n, n * pwb);
    cfg.framework = match args.get_or("framework", "paragan").as_str() {
        "native_tf" => FrameworkProfile::native_tf(),
        "studiogan" => FrameworkProfile::studiogan(),
        _ => FrameworkProfile::paragan(),
    };
    cfg.steps = args.get_usize("sim-steps", 300);
    let r = simulate(&cfg);
    let mut t = Table::new(
        &format!("simulation: {} workers, {} ({})", n, cfg.workload.name, cfg.framework.name),
        &["metric", "value"],
    );
    t.row(vec!["img/s".into(), si(r.img_per_sec)]);
    t.row(vec!["steps/s".into(), f2(r.steps_per_sec)]);
    t.row(vec!["step time (ms)".into(), f2(r.mean_step_time * 1e3)]);
    t.row(vec!["MXU utilization".into(), pct(r.mxu_utilization)]);
    t.row(vec!["MXU occupancy (layout)".into(), pct(r.mxu_occupancy)]);
    t.row(vec!["infeed idle".into(), pct(r.frac_infeed)]);
    t.row(vec!["comm exposed".into(), pct(r.frac_comm)]);
    t.row(vec!["straggler".into(), pct(r.frac_straggler)]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = paragan::runtime::Manifest::load(&dir)?;
    let mut t = Table::new(
        &format!("artifacts in {dir:?} (batch {})", m.batch),
        &["model", "G params", "D params", "loss", "classes", "artifacts"],
    );
    for (name, model) in &m.models {
        t.row(vec![
            name.clone(),
            si(model.n_params_g() as f64),
            si(model.n_params_d() as f64),
            model.loss.clone(),
            model.n_classes.to_string(),
            model.artifacts.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
