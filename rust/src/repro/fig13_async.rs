//! Fig. 13: FID over training for the async update scheme vs sync — REAL
//! training through the AOT artifacts on SNGAN (the paper's Fig. 13 model).
//!
//! Paper findings the shape should reproduce: the async scheme reaches a
//! given FID *earlier* in wall-clock/early steps ("can accelerate
//! convergence ... the benefit is more obvious in the early stage"), while
//! sync is at least as good at the end of training.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::{train_async, train_sync, TrainConfig, TrainResult};
use crate::util::table::{f1, f2, Table};

#[derive(Debug, Clone)]
pub struct Fig13Config {
    pub artifact_dir: PathBuf,
    pub model: String,
    pub steps: u64,
    pub eval_every: u64,
    pub seed: u64,
    /// G:D ratio for the async run (paper sweeps batch-size ratios; with
    /// fixed artifact shapes the equivalent knob is step ratio).
    pub d_steps_per_g: usize,
}

impl Default for Fig13Config {
    fn default() -> Self {
        Fig13Config {
            artifact_dir: PathBuf::from("artifacts"),
            model: "sngan32".into(),
            steps: 120,
            eval_every: 30,
            seed: 23,
            d_steps_per_g: 1,
        }
    }
}

pub fn fig13(cfg: &Fig13Config) -> Result<(Table, Vec<(String, TrainResult)>)> {
    let base = TrainConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        model: cfg.model.clone(),
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        eval_batches: 2,
        seed: cfg.seed,
        log_every: 0,
        ..Default::default()
    };
    let sync_cfg = base.clone();
    let mut async_cfg = base;
    async_cfg.policy = async_cfg.policy.with_d_ratio(cfg.d_steps_per_g);

    let sync_res = train_sync(&sync_cfg)?;
    let async_res = train_async(&async_cfg)?;

    let mut t = Table::new(
        "Fig. 13 — FID-proxy curves: sync vs async update scheme (REAL training)",
        &["scheme", "steps/s", "early FID", "final FID", "mode cov", "mean staleness"],
    );
    for (name, r) in [("sync", &sync_res), ("async", &async_res)] {
        let early = r.fid.points.first().map(|p| p.value).unwrap_or(f64::NAN);
        t.row(vec![
            name.to_string(),
            f2(r.steps_per_sec()),
            f1(early),
            f1(r.final_fid()),
            f2(r.mode_cov.last().unwrap_or(f64::NAN)),
            f2(r.mean_staleness),
        ]);
    }
    Ok((t, vec![("sync".into(), sync_res), ("async".into(), async_res)]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_and_sync_both_converge_at_short_horizon() {
        // Real artifacts when executable, ref set otherwise — never skips,
        // and sngan32 resolves to the actual conv-hinge backbone either way.
        let (dir, model) = crate::testkit::artifacts_for("sngan32").unwrap();
        let cfg = Fig13Config {
            artifact_dir: dir,
            model,
            steps: 6,
            eval_every: 3,
            ..Default::default()
        };
        let (_, results) = fig13(&cfg).unwrap();
        for (name, r) in &results {
            assert!(r.final_fid().is_finite(), "{name}");
            assert!(r.g_loss.points.iter().all(|p| p.value.is_finite()), "{name}");
        }
        // The async run actually exercised staleness machinery.
        let async_r = &results[1].1;
        assert!(!async_r.d_loss.points.is_empty());
    }
}
