//! Fig. 10: MXU utilization of BigGAN-128 under native TF vs ParaGAN across
//! TPU configurations — ParaGAN holds higher utilization and "the gap is
//! increasing" with scale.

use crate::cluster::{biggan, simulate, FrameworkProfile, SimConfig};
use crate::util::table::{pct, Table};

pub fn fig10(per_worker_batch: usize, steps: usize) -> (Table, Vec<(usize, f64, f64)>) {
    let mut t = Table::new(
        "Fig. 10 — MXU utilization: native vs ParaGAN (BigGAN-128)",
        &["workers", "native", "ParaGAN", "gap"],
    );
    let mut rows = Vec::new();
    for n in [8usize, 32, 128, 512, 1024] {
        let mut ours_cfg = SimConfig::tpu_default(biggan(128), n, n * per_worker_batch);
        ours_cfg.steps = steps;
        let mut native_cfg = ours_cfg.clone();
        native_cfg.framework = FrameworkProfile::native_tf();
        let ours = simulate(&ours_cfg);
        let native = simulate(&native_cfg);
        t.row(vec![
            n.to_string(),
            pct(native.mxu_utilization),
            pct(ours.mxu_utilization),
            pct(ours.mxu_utilization - native.mxu_utilization),
        ]);
        rows.push((n, native.mxu_utilization, ours.mxu_utilization));
    }
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paragan_utilization_higher_and_gap_grows() {
        let (_, rows) = fig10(16, 150);
        for (n, native, ours) in &rows {
            assert!(ours > native, "n={n}: {ours} <= {native}");
        }
        let first_gap = rows[0].2 - rows[0].1;
        let last_gap = rows.last().unwrap().2 - rows.last().unwrap().1;
        assert!(last_gap >= first_gap - 0.01, "gap should not shrink: {first_gap} -> {last_gap}");
    }
}
