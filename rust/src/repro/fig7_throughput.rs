//! Fig. 7: throughput of different systems and hardware combinations —
//! native TF (8xV100), StudioGAN (8xV100), ParaGAN (8xV100), ParaGAN
//! (8xTPU).  BigGAN, ImageNet-128 workload.

use crate::cluster::{biggan, simulate, AccelModel, FrameworkProfile, Interconnect, SimConfig, SimReport};
use crate::util::table::{f1, si, Table};

pub fn fig7(per_worker_batch: usize, steps: usize) -> (Table, Vec<(String, SimReport)>) {
    let mut t = Table::new(
        "Fig. 7 — framework throughput, BigGAN ImageNet-128, 8 workers",
        &["system", "hardware", "img/s", "step (ms)", "speedup vs TF"],
    );
    let rows: Vec<(&str, &str, FrameworkProfile, AccelModel, Interconnect)> = vec![
        ("TensorFlow", "8x V100", FrameworkProfile::native_tf(), AccelModel::v100(), Interconnect::nvlink_v100()),
        ("StudioGAN", "8x V100", FrameworkProfile::studiogan(), AccelModel::v100(), Interconnect::nvlink_v100_ddp()),
        ("ParaGAN", "8x V100", FrameworkProfile::paragan(), AccelModel::v100(), Interconnect::nvlink_v100()),
        ("ParaGAN", "8x TPUv3", FrameworkProfile::paragan(), AccelModel::tpu_v3_core(), Interconnect::tpu_v3_pod()),
    ];
    let mut out = Vec::new();
    let mut tf_ips = 0.0;
    for (name, hw, fw, accel, ic) in rows {
        let mut cfg = SimConfig::tpu_default(biggan(128), 8, 8 * per_worker_batch);
        cfg.framework = fw;
        cfg.accel = accel;
        cfg.interconnect = ic;
        cfg.steps = steps;
        let r = simulate(&cfg);
        if name == "TensorFlow" {
            tf_ips = r.img_per_sec;
        }
        t.row(vec![
            name.to_string(),
            hw.to_string(),
            si(r.img_per_sec),
            f1(r.mean_step_time * 1e3),
            format!("{:.2}x", r.img_per_sec / tf_ips),
        ]);
        out.push((format!("{name} ({hw})"), r));
    }
    (t, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ordering_holds() {
        // Paper: ParaGAN > StudioGAN > native TF on GPU; gap "further
        // pronounced when switching to the TPU".
        let (_, rows) = fig7(16, 120);
        let ips: Vec<f64> = rows.iter().map(|(_, r)| r.img_per_sec).collect();
        let (tf, studio, pg_gpu, pg_tpu) = (ips[0], ips[1], ips[2], ips[3]);
        assert!(pg_gpu > studio && studio > tf, "{ips:?}");
        assert!(pg_tpu > pg_gpu, "{ips:?}");
        assert!(pg_gpu / tf > 1.1, "ParaGAN should beat TF by a clear margin");
    }
}
