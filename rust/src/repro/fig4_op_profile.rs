//! Fig. 4: operator usage profile when training at scale — how step time
//! splits between convolution (MXU), vector ops, infeed idle, communication
//! idle, straggling and host overhead as the cluster grows 8 -> 1024.
//! Profiled on the NATIVE framework like the paper ("we profile BigGAN
//! training on native TensorFlow").

use crate::cluster::{biggan, simulate, FrameworkProfile, SimConfig, SimReport};
use crate::util::table::{pct, Table};

pub fn fig4(per_worker_batch: usize, steps: usize) -> (Table, Vec<SimReport>) {
    let mut t = Table::new(
        "Fig. 4 — operator/idle profile vs cluster size (native framework, BigGAN-128)",
        &["workers", "conv (MXU)", "vector", "idle: infeed", "idle: comm", "idle: straggler", "overhead"],
    );
    let mut reports = Vec::new();
    for n in [8usize, 64, 128, 256, 512, 1024] {
        let mut cfg = SimConfig::tpu_default(biggan(128), n, n * per_worker_batch);
        cfg.framework = FrameworkProfile::native_tf();
        cfg.steps = steps;
        let r = simulate(&cfg);
        t.row(vec![
            n.to_string(),
            pct(r.frac_mxu),
            pct(r.frac_vpu),
            pct(r.frac_infeed),
            pct(r.frac_comm),
            pct(r.frac_straggler),
            pct(r.frac_overhead),
        ]);
        reports.push(r);
    }
    (t, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_grows_with_scale_but_conv_dominates() {
        // Paper: "idle time significantly increases due to increased
        // communication, but convolution operation still makes up most of
        // the time" (8 -> 1024 spends 13.6% more on idling).
        let (_, reports) = fig4(16, 150);
        let small = &reports[0];
        let large = reports.last().unwrap();
        let idle = |r: &SimReport| r.frac_infeed + r.frac_comm + r.frac_straggler;
        assert!(idle(large) > idle(small) + 0.03, "{} vs {}", idle(large), idle(small));
        assert!(large.frac_mxu > idle(large), "conv should still dominate");
    }

    #[test]
    fn profile_runs_on_descriptor_derived_dcgan32_shapes() {
        // The op profile also runs on the workload derived from the SAME
        // dcgan32 arch the RefCpuBackend executes — the utilization model
        // and the executable model are one definition.
        let mut cfg =
            crate::cluster::SimConfig::tpu_default(crate::cluster::dcgan32(), 8, 8 * 16);
        cfg.framework = crate::cluster::FrameworkProfile::native_tf();
        cfg.steps = 100;
        let r = crate::cluster::simulate(&cfg);
        assert!(r.frac_mxu > 0.0 && r.frac_mxu <= 1.0, "{}", r.frac_mxu);
        let total = r.frac_mxu
            + r.frac_vpu
            + r.frac_infeed
            + r.frac_comm
            + r.frac_straggler
            + r.frac_overhead;
        assert!((total - 1.0).abs() < 0.05, "fractions sum to {total}");
    }
}
