//! Experiment reproduction harness: one module per table/figure of the
//! paper's evaluation (§6).  Each returns paper-shaped rows as
//! `util::table::Table`s; the bench targets and the `paragan repro` CLI
//! subcommand are thin wrappers over these.
//!
//! Scaling experiments (Figs 1, 4, 7-10, Tables 1-2) run on the cluster
//! simulator (DESIGN.md §1 substitution); numerical experiments (Figs 6,
//! 13) run REAL training through the AOT artifacts; Fig. 11 measures the
//! REAL rust data pipeline under an injected congestion process.

pub mod fig1_weak_scaling;
pub mod fig4_op_profile;
pub mod fig6_optimizers;
pub mod fig7_throughput;
pub mod fig8_strong_scaling;
pub mod fig9_weak_scaling;
pub mod fig10_utilization;
pub mod fig11_pipeline;
pub mod fig13_async;
pub mod table1_models;
pub mod table2_ablation;

pub use fig1_weak_scaling::fig1;
pub use fig4_op_profile::fig4;
pub use fig6_optimizers::{fig6, Fig6Config};
pub use fig7_throughput::fig7;
pub use fig8_strong_scaling::fig8;
pub use fig9_weak_scaling::{fig9, fig9_crosscheck, simulated_dcgan32_efficiency};
pub use fig10_utilization::fig10;
pub use fig11_pipeline::{fig11, Fig11Config};
pub use fig13_async::{fig13, Fig13Config};
pub use table1_models::table1;
pub use table2_ablation::table2;
