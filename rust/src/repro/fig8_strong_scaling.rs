//! Fig. 8: strong scaling — total batch fixed at 512, 150k-step target
//! workload; time-to-solution (a) and img/s (b) as workers grow 16 -> 512.
//! The img/s curve saturates when the per-worker batch hits 1 ("the time
//! spent on communication overweights the computation").

use crate::cluster::{biggan, simulate, SimConfig, SimReport};
use crate::util::table::{f1, f2, si, Table};

pub const PAPER_TOTAL_BATCH: usize = 512;
pub const PAPER_TARGET_STEPS: usize = 150_000;

pub fn fig8(steps: usize) -> (Table, Vec<SimReport>) {
    let mut t = Table::new(
        "Fig. 8 — strong scaling (BigGAN-128, total batch 512, 150k steps)",
        &["workers", "batch/worker", "time-to-solution (h)", "img/s", "step (ms)"],
    );
    let mut reports = Vec::new();
    for n in [16usize, 32, 64, 128, 256, 512] {
        let mut cfg = SimConfig::tpu_default(biggan(128), n, PAPER_TOTAL_BATCH);
        cfg.steps = steps;
        let r = simulate(&cfg);
        t.row(vec![
            n.to_string(),
            (PAPER_TOTAL_BATCH / n).max(1).to_string(),
            f1(r.time_to_steps(PAPER_TARGET_STEPS) / 3600.0),
            si(r.img_per_sec),
            f2(r.mean_step_time * 1e3),
        ]);
        reports.push(r);
    }
    (t, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_to_solution_drops_an_order_of_magnitude() {
        // Paper: "time to solution decreases from over 30 hours to 3 hours".
        let (_, reports) = fig8(120);
        let first = reports[0].time_to_steps(PAPER_TARGET_STEPS);
        let last = reports.last().unwrap().time_to_steps(PAPER_TARGET_STEPS);
        assert!(first / last > 8.0, "speedup {}", first / last);
        assert!(first / 3600.0 > 10.0, "16 workers should take many hours");
    }

    #[test]
    fn img_per_sec_saturates_at_small_per_worker_batch() {
        // Paper: "image per second barely improves" past 128 workers.
        let (_, reports) = fig8(120);
        let r128 = reports.iter().find(|r| r.n_workers == 128).unwrap();
        let r512 = reports.iter().find(|r| r.n_workers == 512).unwrap();
        let gain = r512.img_per_sec / r128.img_per_sec;
        assert!(gain < 2.0, "4x workers should give <2x img/s, got {gain:.2}x");
    }
}
