//! Table 1: reported training time and model size for GANs on ImageNet —
//! the paper's motivation table.  We reproduce the reported columns and add
//! the simulator's estimate of the same workload on the paper's ParaGAN
//! deployment (1024 TPU v3 workers), which is how "15 days -> 14 hours"
//! (abstract) is obtained for BigGAN.

use crate::cluster::{simulate, table1_models, SimConfig};
use crate::util::table::{f1, Table};

/// The paper's BigGAN time-to-convergence workload: ~150k steps at batch
/// 2048 (240 ImageNet epochs).
pub const CONVERGENCE_STEPS: usize = 150_000;

pub fn table1(steps: usize) -> Table {
    let mut t = Table::new(
        "Table 1 — GAN training time / size (paper-reported) + ParaGAN@1024 estimate",
        &["model", "params (M)", "8x V100 (reported)", "ParaGAN 1024 TPU (simulated)", "speedup"],
    );
    for w in table1_models() {
        let reported_h = w.reference_v100_hours.unwrap();
        let mut cfg = SimConfig::tpu_default(w.clone(), 1024, 1024 * 16);
        cfg.steps = steps;
        let r = simulate(&cfg);
        let ours_h = r.time_to_steps(CONVERGENCE_STEPS) / 3600.0;
        t.row(vec![
            w.name.to_string(),
            f1(w.n_params as f64 / 1e6),
            format!("{:.1} d", reported_h / 24.0),
            format!("{ours_h:.1} h"),
            format!("{:.0}x", reported_h / ours_h),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{biggan, simulate, SimConfig};

    #[test]
    fn biggan_goes_from_days_to_hours() {
        // Abstract: "reduce the training time of BigGAN from 15 days to 14
        // hours" — our simulated 1024-worker run should land in the
        // same order of magnitude (hours, not days).
        let mut cfg = SimConfig::tpu_default(biggan(128), 1024, 1024 * 16);
        cfg.steps = 150;
        let r = simulate(&cfg);
        let hours = r.time_to_steps(CONVERGENCE_STEPS) / 3600.0;
        assert!(hours > 2.0 && hours < 40.0, "time-to-solution {hours} h");
    }
}
