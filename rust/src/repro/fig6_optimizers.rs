//! Fig. 6: effect of different optimizer policies — REAL training through
//! the AOT artifacts.  Paper finding: Adam alone reaches low loss then
//! collapses; AdaBelief alone is better; the asymmetric pair (AdaBelief for
//! G + Adam for D) converges to the best equilibrium with the flattest tail.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::{train_sync, OptimizationPolicy, TrainConfig, TrainResult};
use crate::util::table::{f2, f3, Table};

#[derive(Debug, Clone)]
pub struct Fig6Config {
    pub artifact_dir: PathBuf,
    pub model: String,
    pub steps: u64,
    pub seed: u64,
}

impl Default for Fig6Config {
    fn default() -> Self {
        Fig6Config {
            artifact_dir: PathBuf::from("artifacts"),
            model: "dcgan32".into(),
            steps: 120,
            seed: 17,
        }
    }
}

pub fn policies() -> Vec<(&'static str, OptimizationPolicy)> {
    vec![
        ("Adam + Adam", OptimizationPolicy::symmetric("adam")),
        ("AdaBelief + AdaBelief", OptimizationPolicy::symmetric("adabelief")),
        ("RAdam + RAdam", OptimizationPolicy::symmetric("radam")),
        ("AdaBelief(G) + Adam(D)", OptimizationPolicy::paper_asymmetric()),
    ]
}

pub fn fig6(cfg: &Fig6Config) -> Result<(Table, Vec<(String, TrainResult)>)> {
    let mut t = Table::new(
        "Fig. 6 — optimizer policies, REAL training (G loss statistics)",
        &["policy", "final g_loss (ema)", "tail mean", "tail std (stability)", "final FID-proxy"],
    );
    let mut out = Vec::new();
    for (name, policy) in policies() {
        let tc = TrainConfig {
            artifact_dir: cfg.artifact_dir.clone(),
            model: cfg.model.clone(),
            policy,
            steps: cfg.steps,
            seed: cfg.seed,
            eval_batches: 2,
            log_every: 0,
            ..Default::default()
        };
        let r = train_sync(&tc)?;
        t.row(vec![
            name.to_string(),
            f3(r.g_loss.last_smoothed().unwrap_or(f64::NAN)),
            f3(r.g_loss.tail_mean(0.25)),
            f3(r.g_loss.tail_std(0.25)),
            f2(r.final_fid()),
        ]);
        out.push((name.to_string(), r));
    }
    Ok((t, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_policies_train_stably_at_short_horizon() {
        // Real artifacts when executable, ref set otherwise — never skips,
        // and dcgan32 resolves to the actual conv backbone either way.
        let (dir, model) = crate::testkit::artifacts_for("dcgan32").unwrap();
        let cfg = Fig6Config { artifact_dir: dir, model, steps: 4, ..Default::default() };
        let (_, results) = fig6(&cfg).unwrap();
        assert_eq!(results.len(), 4);
        for (name, r) in &results {
            assert!(
                r.g_loss.points.iter().all(|p| p.value.is_finite()),
                "{name} produced non-finite loss"
            );
        }
    }
}
