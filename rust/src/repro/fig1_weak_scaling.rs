//! Fig. 1: "ParaGAN scales to 1024 TPU accelerators at 91% scaling
//! efficiency" — weak scaling of BigGAN-128, constant per-worker batch.

use crate::cluster::{biggan, scaling_efficiency, simulate, SimConfig, SimReport};
use crate::util::table::{f2, pct, si, Table};

pub const PAPER_EFFICIENCY_1024: f64 = 0.91;

pub fn fig1(per_worker_batch: usize, steps: usize) -> (Table, Vec<SimReport>) {
    let mut t = Table::new(
        "Fig. 1 — weak scaling efficiency (BigGAN-128, TPU v3)",
        &["workers", "img/s", "img/s/worker", "efficiency", "step (ms)"],
    );
    let mut reports = Vec::new();
    let mut base: Option<SimReport> = None;
    for n in [8usize, 16, 32, 64, 128, 256, 512, 1024] {
        let mut cfg = SimConfig::tpu_default(biggan(128), n, n * per_worker_batch);
        cfg.steps = steps;
        let r = simulate(&cfg);
        let eff = match &base {
            None => 1.0,
            Some(b) => scaling_efficiency(b, &r),
        };
        if base.is_none() {
            base = Some(r.clone());
        }
        t.row(vec![
            n.to_string(),
            si(r.img_per_sec),
            f2(r.img_per_sec / n as f64),
            pct(eff),
            f2(r.mean_step_time * 1e3),
        ]);
        reports.push(r);
    }
    (t, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::scaling_efficiency;

    #[test]
    fn efficiency_at_1024_close_to_paper() {
        let (_, reports) = fig1(16, 150);
        let base = &reports[0];
        let last = reports.last().unwrap();
        assert_eq!(last.n_workers, 1024);
        let eff = scaling_efficiency(base, last);
        // Paper: 91%. Accept the band around it.
        assert!((eff - PAPER_EFFICIENCY_1024).abs() < 0.06, "eff {eff}");
    }

    #[test]
    fn efficiency_monotonically_degrades() {
        let (_, reports) = fig1(16, 100);
        let base = &reports[0];
        let effs: Vec<f64> =
            reports.iter().map(|r| scaling_efficiency(base, r)).collect();
        for w in effs.windows(2) {
            assert!(w[1] <= w[0] + 0.02, "{effs:?}");
        }
    }
}
