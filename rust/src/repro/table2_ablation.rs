//! Table 2: ablation of the system optimizations — BigGAN-128 on 128 TPU v3
//! accelerators, global batch 2048.  Paper ladder:
//!
//!   baseline 6459 -> +pipeline 7158 (+10.8%) -> +layout 7412 (+3.9%)
//!   -> +bf16 8539 (+15.2%).

use crate::cluster::{biggan, simulate, FrameworkProfile, SimConfig, SimReport};
use crate::util::table::{pct, si, Table};

pub const PAPER_ROWS: [(&str, f64); 4] = [
    ("baseline", 6459.0),
    ("+ data pipelining", 7158.0),
    ("+ layout transformation", 7412.0),
    ("+ mixed precision", 8539.0),
];

pub fn table2(steps: usize) -> (Table, Vec<SimReport>) {
    let mut t = Table::new(
        "Table 2 — ablation of system optimizations (BigGAN-128, 128 TPUv3, batch 2048)",
        &["configuration", "img/s (ours)", "delta (ours)", "img/s (paper)", "delta (paper)"],
    );
    let toggles = [
        (false, false, false),
        (true, false, false),
        (true, true, false),
        (true, true, true),
    ];
    let mut reports = Vec::new();
    let mut prev = 0.0;
    let mut prev_paper = 0.0;
    for ((tuner, layout, bf16), (label, paper_ips)) in toggles.iter().zip(PAPER_ROWS) {
        let mut cfg = SimConfig::tpu_default(biggan(128), 128, 2048);
        cfg.framework = FrameworkProfile::paragan_ablation(*tuner, *layout, *bf16);
        cfg.steps = steps;
        let r = simulate(&cfg);
        let delta = if prev > 0.0 { r.img_per_sec / prev - 1.0 } else { 0.0 };
        let paper_delta = if prev_paper > 0.0 { paper_ips / prev_paper - 1.0 } else { 0.0 };
        t.row(vec![
            label.to_string(),
            si(r.img_per_sec),
            if prev > 0.0 { format!("+{}", pct(delta)) } else { "-".into() },
            si(paper_ips),
            if prev_paper > 0.0 { format!("+{}", pct(paper_delta)) } else { "-".into() },
        ]);
        prev = r.img_per_sec;
        prev_paper = paper_ips;
        reports.push(r);
    }
    (t, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_matches_paper_shape() {
        let (_, reports) = table2(200);
        let ips: Vec<f64> = reports.iter().map(|r| r.img_per_sec).collect();
        // Strictly increasing ladder.
        for w in ips.windows(2) {
            assert!(w[1] > w[0], "{ips:?}");
        }
        // Baseline within 10% of the paper's 6459 (the calibration target).
        assert!((ips[0] / 6459.0 - 1.0).abs() < 0.10, "baseline {}", ips[0]);
        // Full stack within 10% of 8539.
        assert!((ips[3] / 8539.0 - 1.0).abs() < 0.10, "full {}", ips[3]);
        // bf16 delta in the paper's 14-17% band.
        let bf16_delta = ips[3] / ips[2] - 1.0;
        assert!(bf16_delta > 0.10 && bf16_delta < 0.22, "{bf16_delta}");
    }
}
