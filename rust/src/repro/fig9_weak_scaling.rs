//! Fig. 9: weak scaling — per-worker batch fixed at the largest that fits,
//! steps/s (a) and img/s (b) as workers grow to 1024.  "A relatively flat
//! [steps/s] curve indicates that the data pipeline optimization in ParaGAN
//! is effective in case of congestion."

use crate::cluster::{biggan, simulate, SimConfig, SimReport};
use crate::util::table::{f2, si, Table};

pub fn fig9(per_worker_batch: usize, steps: usize) -> (Table, Vec<SimReport>) {
    let mut t = Table::new(
        "Fig. 9 — weak scaling (BigGAN-128, fixed per-worker batch)",
        &["workers", "global batch", "steps/s", "img/s", "step-time cv"],
    );
    let mut reports = Vec::new();
    for n in [8usize, 16, 32, 64, 128, 256, 512, 1024] {
        let mut cfg = SimConfig::tpu_default(biggan(128), n, n * per_worker_batch);
        cfg.steps = steps;
        let r = simulate(&cfg);
        t.row(vec![
            n.to_string(),
            (n * per_worker_batch).to_string(),
            f2(r.steps_per_sec),
            si(r.img_per_sec),
            f2(r.step_time_std / r.mean_step_time),
        ]);
        reports.push(r);
    }
    (t, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steps_per_sec_stays_relatively_flat() {
        let (_, reports) = fig9(16, 150);
        let first = reports[0].steps_per_sec;
        let last = reports.last().unwrap().steps_per_sec;
        // Paper: "the trend in step-per-second is relatively steady even
        // when using 1024 workers" — allow the ~10% efficiency loss.
        assert!(last > 0.85 * first, "steps/s {first} -> {last}");
    }

    #[test]
    fn img_per_sec_scales_linearly() {
        let (_, reports) = fig9(16, 150);
        let per8 = reports[0].img_per_sec / 8.0;
        let per1024 = reports.last().unwrap().img_per_sec / 1024.0;
        assert!(per1024 > 0.85 * per8);
    }
}
