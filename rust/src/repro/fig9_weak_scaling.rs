//! Fig. 9: weak scaling — per-worker batch fixed at the largest that fits,
//! steps/s (a) and img/s (b) as workers grow to 1024.  "A relatively flat
//! [steps/s] curve indicates that the data pipeline optimization in ParaGAN
//! is effective in case of congestion."

use std::path::Path;

use crate::cluster::{biggan, scaling_efficiency, simulate, SimConfig, SimReport};
use crate::util::json;
use crate::util::table::{f2, pct, si, Table};

pub fn fig9(per_worker_batch: usize, steps: usize) -> (Table, Vec<SimReport>) {
    let mut t = Table::new(
        "Fig. 9 — weak scaling (BigGAN-128, fixed per-worker batch)",
        &["workers", "global batch", "steps/s", "img/s", "step-time cv"],
    );
    let mut reports = Vec::new();
    for n in [8usize, 16, 32, 64, 128, 256, 512, 1024] {
        let mut cfg = SimConfig::tpu_default(biggan(128), n, n * per_worker_batch);
        cfg.steps = steps;
        let r = simulate(&cfg);
        t.row(vec![
            n.to_string(),
            (n * per_worker_batch).to_string(),
            f2(r.steps_per_sec),
            si(r.img_per_sec),
            f2(r.step_time_std / r.mean_step_time),
        ]);
        reports.push(r);
    }
    (t, reports)
}

/// Simulator-predicted weak-scaling efficiency at `n` workers of the
/// dcgan32 topology (per-worker batch fixed), relative to 1 worker — the
/// prediction `BENCH_dist.json`'s measured runs are checked against.
pub fn simulated_dcgan32_efficiency(n: usize, per_worker_batch: usize, steps: usize) -> f64 {
    let run = |workers: usize| {
        let mut cfg = SimConfig::tpu_default(
            crate::cluster::dcgan32(),
            workers,
            workers * per_worker_batch,
        );
        cfg.steps = steps;
        cfg.warmup = (steps / 4).max(10);
        simulate(&cfg)
    };
    scaling_efficiency(&run(1), &run(n))
}

/// Measured-vs-simulated drift report: when a `BENCH_dist.json` written by
/// `bench_dist_scaling` is present, compare each measured SYNC run's
/// weak-scaling efficiency against the simulator's prediction for the same
/// worker count and flag (warn, never fail) any drift above 15%.  Returns
/// `None` when the file is absent or holds no sync runs.
pub fn fig9_crosscheck(bench_path: &Path) -> Option<Table> {
    let text = std::fs::read_to_string(bench_path).ok()?;
    let root = json::parse(&text).ok()?;
    if root.get("format").as_str() != Some("paragan-bench-dist") {
        return None;
    }
    let batch = root.get("batch").as_usize().unwrap_or(8);
    let runs = root.get("runs").as_arr()?;
    let mut t = Table::new(
        "Fig. 9 cross-check — measured dist sync vs simulator prediction",
        &["replicas", "measured eff", "simulated eff", "delta", "verdict"],
    );
    let mut any = false;
    for run in runs {
        if run.get("mode").as_str() != Some("sync") {
            continue;
        }
        let (Some(n), Some(measured)) =
            (run.get("replicas").as_usize(), run.get("efficiency").as_f64())
        else {
            continue;
        };
        if n < 2 {
            continue; // the n=1 baseline defines efficiency 1.0 on both sides
        }
        // Prefer the prediction the bench recorded NEXT TO the measurement
        // (same simulator settings); recompute only for older files that
        // lack it (-1.0 / absent = not recorded).
        let sim = run
            .get("sim_efficiency")
            .as_f64()
            .filter(|&v| v >= 0.0)
            .unwrap_or_else(|| simulated_dcgan32_efficiency(n, batch, 150));
        let delta = measured - sim;
        let verdict = if delta.abs() > 0.15 {
            "WARN: drift > 15% (in-process replicas share one host; see README)"
        } else {
            "ok"
        };
        t.row(vec![n.to_string(), pct(measured), pct(sim), pct(delta), verdict.into()]);
        if delta.abs() > 0.15 {
            log::warn!(
                "dist sync {n}-replica measured efficiency {measured:.2} drifts \
                 {delta:+.2} from the fig9 simulator's {sim:.2}"
            );
        }
        any = true;
    }
    any.then_some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steps_per_sec_stays_relatively_flat() {
        let (_, reports) = fig9(16, 150);
        let first = reports[0].steps_per_sec;
        let last = reports.last().unwrap().steps_per_sec;
        // Paper: "the trend in step-per-second is relatively steady even
        // when using 1024 workers" — allow the ~10% efficiency loss.
        assert!(last > 0.85 * first, "steps/s {first} -> {last}");
    }

    #[test]
    fn img_per_sec_scales_linearly() {
        let (_, reports) = fig9(16, 150);
        let per8 = reports[0].img_per_sec / 8.0;
        let per1024 = reports.last().unwrap().img_per_sec / 1024.0;
        assert!(per1024 > 0.85 * per8);
    }

    #[test]
    fn simulated_dcgan32_efficiency_is_sane() {
        let eff = simulated_dcgan32_efficiency(4, 8, 120);
        assert!(eff > 0.5 && eff <= 1.001, "{eff}");
    }

    #[test]
    fn crosscheck_reads_bench_dist_json() {
        let dir = std::env::temp_dir()
            .join(format!("paragan-fig9-xcheck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_dist.json");
        // Absent file -> None.
        assert!(fig9_crosscheck(&path).is_none());
        // A wrong-format file -> None.
        std::fs::write(&path, r#"{"format":"other"}"#).unwrap();
        assert!(fig9_crosscheck(&path).is_none());
        // A plausible measured set: 1-replica baseline is skipped, the
        // 2-replica sync row is compared (warn-only either way).
        // Recorded sim_efficiency is used verbatim (0.97 vs measured 0.82
        // → delta within 15% → "ok"); no simulator recompute.
        std::fs::write(
            &path,
            r#"{"format":"paragan-bench-dist","version":1,"batch":8,
                "runs":[
                  {"mode":"sync","replicas":1,"efficiency":1.0,"sim_efficiency":1.0},
                  {"mode":"sync","replicas":2,"efficiency":0.82,"sim_efficiency":0.97},
                  {"mode":"async","replicas":2,"efficiency":0.9}]}"#,
        )
        .unwrap();
        let t = fig9_crosscheck(&path).expect("sync rows present");
        assert_eq!(t.rows.len(), 1, "only the 2-replica sync row qualifies");
        assert_eq!(t.rows[0][0], "2");
        assert_eq!(t.rows[0][4], "ok", "{:?}", t.rows[0]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
