//! Fig. 11: data-pipeline batch-extraction latency, tf.data-style static
//! pipeline vs ParaGAN's congestion-aware tuner — MEASURED on the real rust
//! pipeline (threads, sleeps, tuner resizing live), with both pipelines
//! driven by identical Markov congestion processes.

use std::sync::Arc;

use crate::pipeline::{
    CongestionModel, DataPipeline, MarkovCongestion, PipelineConfig, StorageNode, SynthImages,
    TunerConfig,
};
use crate::util::stats::Sample;
use crate::util::table::{f2, f3, Table};

#[derive(Debug, Clone)]
pub struct Fig11Config {
    pub batches: usize,
    pub batch_size: usize,
    /// Scaled-down congestion process (real sleeps; keep medians small).
    pub congestion: CongestionModel,
    pub seed: u64,
}

impl Default for Fig11Config {
    fn default() -> Self {
        Fig11Config {
            batches: 150,
            batch_size: 16,
            congestion: CongestionModel {
                base_median: 300e-6,
                base_sigma: 0.3,
                congested_factor: 5.0,
                congested_sigma: 0.6,
                // Episodes of ~8 batches so a short run sees several
                // congestion cycles the tuner can react to.
                p_enter: 0.004,
                p_exit: 0.008,
            },
            seed: 0xF11,
        }
    }
}

pub struct Fig11Result {
    pub static_lat: Sample,
    pub tuned_lat: Sample,
    pub tuned_grows: u64,
    pub tuned_final_workers: usize,
}

fn run_pipeline(cfg: &Fig11Config, tuned: bool) -> (Sample, Option<(u64, u64, usize)>) {
    let node = Arc::new(StorageNode::new(
        Box::new(SynthImages::new32(8, cfg.seed)),
        Box::new(MarkovCongestion::new(cfg.congestion.clone(), cfg.seed ^ 0x77)),
        true,
    ));
    let p = DataPipeline::start(
        node,
        PipelineConfig {
            batch_size: cfg.batch_size,
            initial_workers: 2,
            initial_buffer: 8,
            tuner: tuned.then(|| TunerConfig {
                window: 16,
                cooldown: 8,
                min_workers: 2,
                max_workers: 16,
                ..Default::default()
            }),
        },
    );
    // Consume batches at a trainer-like cadence: a small compute pause per
    // batch so the prefetch pool actually races the consumer.
    for _ in 0..cfg.batches {
        p.next_batch().expect("batch");
        std::thread::sleep(std::time::Duration::from_micros(
            (cfg.batch_size as u64) * 150,
        ));
    }
    let lat = p.take_extract_latencies();
    let stats = p.tuner_stats();
    p.shutdown();
    (lat, stats)
}

pub fn fig11(cfg: &Fig11Config) -> (Table, Fig11Result) {
    let (static_lat, _) = run_pipeline(cfg, false);
    let (tuned_lat, stats) = run_pipeline(cfg, true);
    let (grows, _shrinks, final_workers) = stats.unwrap_or((0, 0, 0));

    let mut t = Table::new(
        "Fig. 11 — batch extraction latency under congestion (REAL pipeline)",
        &["pipeline", "mean (ms)", "p50 (ms)", "p99 (ms)", "std (ms)", "cv"],
    );
    let mut row = |name: &str, s: &mut Sample| {
        let mean = s.mean();
        t.row(vec![
            name.to_string(),
            f3(mean * 1e3),
            f3(s.quantile(0.5) * 1e3),
            f3(s.quantile(0.99) * 1e3),
            f3(s.std() * 1e3),
            f2(if mean > 0.0 { s.std() / mean } else { 0.0 }),
        ]);
    };
    let mut s = static_lat.clone();
    let mut d = tuned_lat.clone();
    row("static (tf.data-like)", &mut s);
    row("ParaGAN tuner", &mut d);
    (
        t,
        Fig11Result { static_lat, tuned_lat, tuned_grows: grows, tuned_final_workers: final_workers },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuner_reduces_latency_variability() {
        // Paper: "our pipeline tuner has a lower variance in latency".
        // This is a REAL-TIME measurement (thread sleeps); under heavy CI
        // contention single runs are noisy, so accept a pass on either of
        // two seeds and judge on mean + (std OR p99).
        let mut last = String::new();
        for seed in [0xF11u64, 0xF12] {
            let cfg = Fig11Config { batches: 120, seed, ..Default::default() };
            let (_, res) = fig11(&cfg);
            let mut s = res.static_lat.clone();
            let mut d = res.tuned_lat.clone();
            let mean_ok = d.mean() < s.mean();
            let tail_ok = d.std() < s.std() || d.quantile(0.99) < s.quantile(0.99);
            if mean_ok && tail_ok && res.tuned_grows > 0 {
                return;
            }
            last = format!(
                "seed {seed:#x}: tuned mean {:.4} std {:.4} p99 {:.4} vs static mean {:.4} std {:.4} p99 {:.4} (grows {})",
                d.mean(), d.std(), d.quantile(0.99),
                s.mean(), s.std(), s.quantile(0.99), res.tuned_grows
            );
        }
        panic!("tuner did not beat static pipeline on any seed: {last}");
    }
}
