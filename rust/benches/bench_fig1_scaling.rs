//! cargo bench target regenerating the paper's Fig. 1 — weak scaling to 1024 workers (see repro::fig1).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Fig. 1 — weak scaling to 1024 workers");
    let (table, _) = paragan::repro::fig1(16, 300);
    rep.table(table);
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("fig1 (simulator sweep)", &cfg, || {
        let _ = paragan::repro::fig1(16, 60);
    }));
    rep.note("paper: 91% efficiency at 1024 TPU accelerators");
    rep.finish();
}
