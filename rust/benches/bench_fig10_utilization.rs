//! cargo bench target regenerating the paper's Fig. 10 — MXU utilization native vs ParaGAN (see repro::fig10).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Fig. 10 — MXU utilization native vs ParaGAN");
    let (table, _) = paragan::repro::fig10(16, 300);
    rep.table(table);
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("fig10 (simulator sweep)", &cfg, || {
        let _ = paragan::repro::fig10(16, 60);
    }));
    rep.note("paper: ParaGAN holds higher MXU utilization; gap grows with scale");
    rep.finish();
}
