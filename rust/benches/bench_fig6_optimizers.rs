//! cargo bench target regenerating the paper's Fig. 6 (optimizer policies) —
//! REAL training through the AOT artifacts.  Horizon is scaled to this
//! single-CPU testbed; pass more steps via PARAGAN_FIG6_STEPS.
use paragan::bench::Reporter;
use paragan::repro::{fig6, Fig6Config};

fn main() {
    let steps = std::env::var("PARAGAN_FIG6_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(60);
    let mut rep = Reporter::new("Fig. 6 — asymmetric optimizer policy (real training)");
    // Resolve dcgan32 in the executable artifact set (ref conv artifacts on
    // a clean checkout) — unknown models are a hard error, not a skip.
    let (dir, model) = match paragan::testkit::artifacts_for("dcgan32") {
        Ok(found) => found,
        Err(e) => {
            rep.note(format!("SKIPPED: {e}"));
            rep.finish();
            return;
        }
    };
    let cfg = Fig6Config { steps, artifact_dir: dir, model, ..Default::default() };
    match fig6(&cfg) {
        Ok((table, results)) => {
            rep.table(table);
            for (name, r) in &results {
                rep.note(format!(
                    "{name}: {:.2} steps/s, collapsed={}",
                    r.steps_per_sec(),
                    r.g_loss.collapsed(2.0)
                ));
            }
            rep.note("paper: asymmetric AdaBelief(G)+Adam(D) reaches the best, flattest equilibrium");
        }
        Err(e) => rep.note(format!("SKIPPED: {e} (run `make artifacts`)")),
    }
    rep.finish();
}
