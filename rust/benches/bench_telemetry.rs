//! Telemetry overhead bench (PR-9): dcgan32 sync training steps/sec with
//! span/counter recording ON vs OFF, written to `BENCH_telemetry.json`.
//!
//! Always-on observability is only tenable if it is effectively free, so
//! this bench is the contract's enforcement point: the ON arm must land
//! within 2% of the OFF arm (CI gate, exit 1), with a 1% target recorded in
//! the JSON.  The OFF arm is `telemetry::set_enabled(Some(false))` — every
//! record site degrades to a single relaxed atomic load — which is exactly
//! the same A/B shape as the workspace arena's `set_arena_mode` bench.
//!
//! Protocol: interleaved OFF/ON trials (alternation cancels slow drift —
//! thermal, page cache, pool warmup), best-of per arm (discards scheduler
//! hiccups; throughput noise is one-sided).  The ON arm also asserts that
//! spans were actually recorded, so the gate can never silently pass by
//! measuring two OFF runs.  `--test` runs the smoke-sized protocol.

use paragan::coordinator::{train_sync, TrainConfig};
use paragan::telemetry;
use paragan::util::json::{num, obj, s as js, write_json};
use paragan::util::table::Table;

/// Hard CI gate: recording may cost at most this fraction of throughput.
const MAX_OVERHEAD: f64 = 0.02;
/// Soft target recorded in the JSON (noted, not gated).
const TARGET_OVERHEAD: f64 = 0.01;

fn steps_per_sec(steps: u64, seed: u64) -> f64 {
    let (dir, model) = paragan::testkit::artifacts_for("dcgan32").expect("dcgan32 artifacts");
    let cfg = TrainConfig {
        artifact_dir: dir,
        model,
        steps,
        seed,
        eval_batches: 2,
        log_every: 0,
        ..Default::default()
    };
    train_sync(&cfg).expect("dcgan32 train run").steps_per_sec()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let steps: u64 = if smoke { 6 } else { 40 };
    let trials: u64 = if smoke { 2 } else { 3 };
    println!("== telemetry overhead bench{} ==", if smoke { " (smoke)" } else { "" });

    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    let mut events_on = 0u64;
    for trial in 0..trials {
        telemetry::set_enabled(Some(false));
        best_off = best_off.max(steps_per_sec(steps, 50 + trial));
        telemetry::set_enabled(Some(true));
        // Quiescent: the OFF run's trainer thread has joined; reset so the
        // final report describes exactly one ON run.
        telemetry::reset();
        best_on = best_on.max(steps_per_sec(steps, 50 + trial));
        events_on = events_on.max(telemetry::events_recorded());
    }
    let rep = telemetry::report();
    telemetry::set_enabled(None);

    let overhead = 1.0 - best_on / best_off.max(1e-12);
    let meets_gate = overhead <= MAX_OVERHEAD;
    let meets_target = overhead <= TARGET_OVERHEAD;

    let mut t = Table::new("dcgan32 telemetry recording overhead", &["metric", "value"]);
    t.row(vec!["steps/s, recording off (best)".into(), format!("{best_off:.2}")]);
    t.row(vec!["steps/s, recording on (best)".into(), format!("{best_on:.2}")]);
    t.row(vec!["overhead".into(), format!("{:.2}%", overhead * 100.0)]);
    t.row(vec!["gate (max)".into(), format!("{:.0}%", MAX_OVERHEAD * 100.0)]);
    t.row(vec!["target".into(), format!("{:.0}%", TARGET_OVERHEAD * 100.0)]);
    t.row(vec!["events recorded (on arm)".into(), events_on.to_string()]);
    t.row(vec!["events dropped".into(), rep.dropped.to_string()]);
    println!("{}", t.render());
    println!("{}", rep.render());

    let json = obj(vec![
        ("format", js("paragan-bench-telemetry")),
        ("version", num(1.0)),
        ("smoke", js(if smoke { "true" } else { "false" })),
        ("model", js("dcgan32")),
        ("steps", num(steps as f64)),
        ("trials", num(trials as f64)),
        ("telemetry_off_steps_per_sec", num(best_off)),
        ("telemetry_on_steps_per_sec", num(best_on)),
        ("overhead_frac", num(overhead)),
        ("max_overhead_frac", num(MAX_OVERHEAD)),
        ("target_overhead_frac", num(TARGET_OVERHEAD)),
        ("meets_gate", js(if meets_gate { "true" } else { "false" })),
        ("meets_target", js(if meets_target { "true" } else { "false" })),
        ("events_recorded", num(events_on as f64)),
        ("dropped_events", num(rep.dropped as f64)),
        ("phases", rep.phases_json()),
    ]);
    let mut text = String::new();
    write_json(&json, &mut text);
    text.push('\n');
    std::fs::write("BENCH_telemetry.json", &text).expect("writing BENCH_telemetry.json");
    println!("wrote BENCH_telemetry.json");

    let mut failed = false;
    if events_on == 0 {
        eprintln!("FAIL: the ON arm recorded no telemetry events — the gate measured nothing");
        failed = true;
    }
    if !meets_gate {
        eprintln!(
            "FAIL: telemetry overhead {:.2}% exceeds the {:.0}% gate \
             (off {best_off:.2} vs on {best_on:.2} steps/s)",
            overhead * 100.0,
            MAX_OVERHEAD * 100.0
        );
        failed = true;
    }
    if meets_gate && !meets_target {
        eprintln!(
            "note: overhead {:.2}% above the {:.0}% target (recorded, gated at {:.0}%)",
            overhead * 100.0,
            TARGET_OVERHEAD * 100.0,
            MAX_OVERHEAD * 100.0
        );
    }
    if failed {
        std::process::exit(1);
    }
}
