//! cargo bench target regenerating the paper's Fig. 9 — weak scaling steps/s + img/s (see repro::fig9).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Fig. 9 — weak scaling steps/s + img/s");
    let (table, _) = paragan::repro::fig9(16, 300);
    rep.table(table);
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("fig9 (simulator sweep)", &cfg, || {
        let _ = paragan::repro::fig9(16, 60);
    }));
    rep.note("paper: flat steps/s curve to 1024 workers");
    rep.finish();
}
