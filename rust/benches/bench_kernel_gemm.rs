//! Kernel-layer bench: naive triple-loop GEMM vs the planned, packed,
//! parallel `runtime::kernel::Gemm` engine at the dcgan32 im2col shapes,
//! plus real dcgan32 train-step throughput in three kernel modes (naive /
//! planned threads=1 / planned all-cores).  Writes `BENCH_kernels.json` —
//! the seed of the perf trajectory — and exits non-zero if the planned
//! engine is slower than the naive baseline over the dcgan32 shape set
//! (the CI gate).
//!
//! `--test` runs a smoke-sized version of the same protocol.

use paragan::bench::{bench, BenchConfig, Reporter};
use paragan::coordinator::{train_sync, TrainConfig};
use paragan::layout::cost::LayerShape;
use paragan::runtime::kernel::{self, Gemm, KernelConfig};
use paragan::runtime::refgen::{
    arch_layer_shapes, dcgan32_d_net, dcgan32_g_net, DCGAN32_Z_DIM, REF_BATCH,
};
use paragan::util::json::{arr, num, obj, s as js, write_json, Json};
use paragan::util::rng::Rng;
use paragan::util::table::Table;

/// dcgan32's matmul shapes — the shapes the acceptance gate runs at:
/// `(name, m, k, n, ta)` with `ta` marking the transposed-A orientation.
/// Forward im2col GEMMs of G and D at the ref batch, plus one
/// weight-gradient GEMM (dW = doutT x cols of d.conv0) run as real TN so
/// the gate also covers the transposed pack path.
fn dcgan32_gemm_shapes(batch: usize) -> Vec<(String, usize, usize, usize, bool)> {
    let mut shapes = Vec::new();
    for (prefix, net) in [("g", dcgan32_g_net(DCGAN32_Z_DIM)), ("d", dcgan32_d_net())] {
        for l in arch_layer_shapes(&net, prefix, 1) {
            shapes.push((l.name.clone(), l.m_per_sample * batch, l.k, l.n, false));
        }
    }
    let d0: LayerShape = arch_layer_shapes(&dcgan32_d_net(), "d", 1)
        .into_iter()
        .next()
        .expect("dcgan32 D has conv layers");
    shapes.push((
        format!("{}.dw", d0.name),
        d0.n,
        d0.m_per_sample * batch,
        d0.k,
        true,
    ));
    shapes
}

fn train_steps_per_sec(steps: u64, seed: u64) -> f64 {
    let (dir, model) = paragan::testkit::artifacts_for("dcgan32").expect("dcgan32 artifacts");
    let cfg = TrainConfig {
        artifact_dir: dir,
        model,
        steps,
        seed,
        eval_batches: 2,
        log_every: 0,
        ..Default::default()
    };
    let res = train_sync(&cfg).expect("dcgan32 train run");
    res.steps_per_sec()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut rep = Reporter::new(if smoke {
        "Kernel GEMM — naive vs planned (smoke)"
    } else {
        "Kernel GEMM — naive vs planned"
    });
    let threads = KernelConfig::current().threads;
    let bench_cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 8,
            target_time: std::time::Duration::from_millis(200),
        }
    } else {
        BenchConfig { min_iters: 10, max_iters: 200, ..Default::default() }
    };

    // --- GEMM micro-bench over the dcgan32 shapes ---
    let mut t = Table::new(
        "dcgan32 GEMM shapes: naive vs planned engine",
        &["shape", "m", "k", "n", "naive", "planned", "speedup"],
    );
    let mut gemm_rows: Vec<Json> = Vec::new();
    let (mut naive_total_ns, mut planned_total_ns) = (0.0f64, 0.0f64);
    let mut rng = Rng::new(0xBE7C);
    for (name, m, k, n, ta) in dcgan32_gemm_shapes(REF_BATCH) {
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_gaussian(&mut a, 0.0, 1.0);
        rng.fill_gaussian(&mut b, 0.0, 1.0);
        let rn = bench(&format!("naive {name}"), &bench_cfg, || {
            let _ = kernel::naive::gemm(m, k, n, &a, ta, &b, false);
        });
        let g = Gemm::plan_with(KernelConfig::with_threads(threads), m, k, n);
        let rp = bench(&format!("planned {name}"), &bench_cfg, || {
            let _ = g.run(&a, ta, &b, false);
        });
        let speedup = rn.mean_ns / rp.mean_ns;
        t.row(vec![
            name.clone(),
            m.to_string(),
            k.to_string(),
            n.to_string(),
            format!("{:.1} us", rn.mean_ns / 1e3),
            format!("{:.1} us", rp.mean_ns / 1e3),
            format!("{speedup:.2}x"),
        ]);
        gemm_rows.push(obj(vec![
            ("name", js(&name)),
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
            ("ta", js(if ta { "true" } else { "false" })),
            ("naive_ns", num(rn.mean_ns)),
            ("planned_ns", num(rp.mean_ns)),
            ("speedup", num(speedup)),
        ]));
        naive_total_ns += rn.mean_ns;
        planned_total_ns += rp.mean_ns;
    }
    rep.table(t);
    let gemm_speedup = naive_total_ns / planned_total_ns.max(1.0);
    rep.note(format!(
        "gemm aggregate speedup over dcgan32 shapes: {gemm_speedup:.2}x ({threads} threads)"
    ));

    // --- dcgan32 train-step throughput: naive vs planned t=1 vs planned ---
    let steps = if smoke { 6 } else { 40 };
    kernel::set_naive_mode(true);
    let naive_sps = train_steps_per_sec(steps, 41);
    kernel::set_naive_mode(false);
    kernel::set_threads(Some(1));
    let t1_sps = train_steps_per_sec(steps, 42);
    kernel::set_threads(None);
    let planned_sps = train_steps_per_sec(steps, 43);
    let train_speedup = planned_sps / naive_sps;
    let t1_speedup = t1_sps / naive_sps;
    let mut t = Table::new(
        "dcgan32 train-step throughput (sync, ref backend)",
        &["kernel mode", "steps/s", "vs naive"],
    );
    t.row(vec!["naive loops".into(), format!("{naive_sps:.2}"), "1.00x".into()]);
    t.row(vec![
        "planned, threads=1".into(),
        format!("{t1_sps:.2}"),
        format!("{t1_speedup:.2}x"),
    ]);
    t.row(vec![
        format!("planned, threads={threads}"),
        format!("{planned_sps:.2}"),
        format!("{train_speedup:.2}x"),
    ]);
    rep.table(t);
    rep.note(format!(
        "train-step speedup {train_speedup:.2}x (threads={threads}); threads=1 {t1_speedup:.2}x"
    ));

    // --- BENCH_kernels.json ---
    let json = obj(vec![
        ("format", js("paragan-bench-kernels")),
        ("version", num(1.0)),
        ("smoke", js(if smoke { "true" } else { "false" })),
        ("threads", num(threads as f64)),
        ("batch", num(REF_BATCH as f64)),
        ("gemm", arr(gemm_rows)),
        ("gemm_total_speedup", num(gemm_speedup)),
        (
            "train",
            obj(vec![
                ("model", js("dcgan32")),
                ("steps", num(steps as f64)),
                ("naive_steps_per_sec", num(naive_sps)),
                ("planned_t1_steps_per_sec", num(t1_sps)),
                ("planned_steps_per_sec", num(planned_sps)),
                ("t1_speedup", num(t1_speedup)),
                ("speedup", num(train_speedup)),
            ]),
        ),
    ]);
    let mut text = String::new();
    write_json(&json, &mut text);
    text.push('\n');
    std::fs::write("BENCH_kernels.json", &text).expect("writing BENCH_kernels.json");
    rep.note("wrote BENCH_kernels.json");
    rep.finish();

    // CI gate: the planned engine must not lose to the naive loops over
    // the dcgan32 shape set.
    if planned_total_ns > naive_total_ns {
        eprintln!(
            "FAIL: planned GEMM slower than naive over dcgan32 shapes \
             ({:.1} us vs {:.1} us)",
            planned_total_ns / 1e3,
            naive_total_ns / 1e3
        );
        std::process::exit(1);
    }
}
