//! Kernel-layer bench: naive triple-loop GEMM vs the planned engine's two
//! lanes (exact and SIMD/FMA fast) at the dcgan32 im2col shapes, plus real
//! dcgan32 train-step throughput in four kernel modes (naive / exact
//! threads=1 / exact all-cores / simd all-cores).  Writes
//! `BENCH_kernels.json` (schema v2: per-shape naive vs exact vs simd) — the
//! perf trajectory record — and exits non-zero if (a) the exact lane loses
//! to the naive loops, or (b) the fast lane misses its recorded multiple
//! over the exact lane on a SIMD-capable host (the CI gates).
//!
//! `--test` runs a smoke-sized version of the same protocol (the fast-lane
//! gate relaxes to "not slower" there; the 1.5x target applies to full runs).

use paragan::bench::{bench, BenchConfig, Reporter};
use paragan::coordinator::{train_sync, TrainConfig};
use paragan::layout::cost::LayerShape;
use paragan::layout::plan::KernelLane;
use paragan::runtime::kernel::{self, Gemm, KernelConfig};
use paragan::runtime::refgen::{
    arch_layer_shapes, dcgan32_d_net, dcgan32_g_net, DCGAN32_Z_DIM, REF_BATCH,
};
use paragan::util::json::{arr, num, obj, s as js, write_json, Json};
use paragan::util::rng::Rng;
use paragan::util::table::Table;

/// The fast lane's recorded target multiple over the exact lane on the
/// dcgan32 GEMM shapes (full runs, SIMD-capable hosts).
const FAST_TARGET: f64 = 1.5;

/// dcgan32's matmul shapes — the shapes the acceptance gate runs at:
/// `(name, m, k, n, ta)` with `ta` marking the transposed-A orientation.
/// Forward im2col GEMMs of G and D at the ref batch, plus one
/// weight-gradient GEMM (dW = doutT x cols of d.conv0) run as real TN so
/// the gate also covers the transposed pack path.
fn dcgan32_gemm_shapes(batch: usize) -> Vec<(String, usize, usize, usize, bool)> {
    let mut shapes = Vec::new();
    for (prefix, net) in [("g", dcgan32_g_net(DCGAN32_Z_DIM)), ("d", dcgan32_d_net())] {
        for l in arch_layer_shapes(&net, prefix, 1) {
            shapes.push((l.name.clone(), l.m_per_sample * batch, l.k, l.n, false));
        }
    }
    let d0: LayerShape = arch_layer_shapes(&dcgan32_d_net(), "d", 1)
        .into_iter()
        .next()
        .expect("dcgan32 D has conv layers");
    shapes.push((
        format!("{}.dw", d0.name),
        d0.n,
        d0.m_per_sample * batch,
        d0.k,
        true,
    ));
    shapes
}

fn train_steps_per_sec(steps: u64, seed: u64) -> f64 {
    let (dir, model) = paragan::testkit::artifacts_for("dcgan32").expect("dcgan32 artifacts");
    let cfg = TrainConfig {
        artifact_dir: dir,
        model,
        steps,
        seed,
        eval_batches: 2,
        log_every: 0,
        ..Default::default()
    };
    let res = train_sync(&cfg).expect("dcgan32 train run");
    res.steps_per_sec()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut rep = Reporter::new(if smoke {
        "Kernel GEMM — naive vs exact vs simd (smoke)"
    } else {
        "Kernel GEMM — naive vs exact vs simd"
    });
    let threads = KernelConfig::current().threads;
    let simd_available = kernel::simd_available();
    let bench_cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 8,
            target_time: std::time::Duration::from_millis(200),
        }
    } else {
        BenchConfig { min_iters: 10, max_iters: 200, ..Default::default() }
    };

    // --- GEMM micro-bench over the dcgan32 shapes, all three engines ---
    let mut t = Table::new(
        "dcgan32 GEMM shapes: naive vs exact lane vs simd lane",
        &["shape", "m", "k", "n", "naive", "exact", "simd", "ex/naive", "simd/ex"],
    );
    let mut gemm_rows: Vec<Json> = Vec::new();
    let (mut naive_total_ns, mut exact_total_ns, mut simd_total_ns) = (0.0f64, 0.0f64, 0.0f64);
    let mut rng = Rng::new(0xBE7C);
    for (name, m, k, n, ta) in dcgan32_gemm_shapes(REF_BATCH) {
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_gaussian(&mut a, 0.0, 1.0);
        rng.fill_gaussian(&mut b, 0.0, 1.0);
        let rn = bench(&format!("naive {name}"), &bench_cfg, || {
            let _ = kernel::naive::gemm(m, k, n, &a, ta, &b, false);
        });
        let ge = Gemm::plan_with(KernelConfig::with_threads(threads), m, k, n);
        let re = bench(&format!("exact {name}"), &bench_cfg, || {
            let _ = ge.run(&a, ta, &b, false);
        });
        // On a non-SIMD host the Simd request degrades to the exact lane
        // (resolve_lane), so this column then re-measures the exact engine;
        // the JSON records `simd_available` so readers can tell.
        let gs = Gemm::plan_with(
            KernelConfig::with_threads_lane(threads, KernelLane::Simd),
            m,
            k,
            n,
        );
        let rs = bench(&format!("simd {name}"), &bench_cfg, || {
            let _ = gs.run(&a, ta, &b, false);
        });
        let exact_speedup = rn.mean_ns / re.mean_ns;
        let fast_vs_exact = re.mean_ns / rs.mean_ns;
        t.row(vec![
            name.clone(),
            m.to_string(),
            k.to_string(),
            n.to_string(),
            format!("{:.1} us", rn.mean_ns / 1e3),
            format!("{:.1} us", re.mean_ns / 1e3),
            format!("{:.1} us", rs.mean_ns / 1e3),
            format!("{exact_speedup:.2}x"),
            format!("{fast_vs_exact:.2}x"),
        ]);
        gemm_rows.push(obj(vec![
            ("name", js(&name)),
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
            ("ta", js(if ta { "true" } else { "false" })),
            ("naive_ns", num(rn.mean_ns)),
            ("exact_ns", num(re.mean_ns)),
            ("simd_ns", num(rs.mean_ns)),
            ("exact_speedup", num(exact_speedup)),
            ("fast_vs_exact", num(fast_vs_exact)),
        ]));
        naive_total_ns += rn.mean_ns;
        exact_total_ns += re.mean_ns;
        simd_total_ns += rs.mean_ns;
    }
    rep.table(t);
    let gemm_speedup = naive_total_ns / exact_total_ns.max(1.0);
    let fast_speedup = exact_total_ns / simd_total_ns.max(1.0);
    rep.note(format!(
        "exact lane {gemm_speedup:.2}x over naive; fast lane {fast_speedup:.2}x over exact \
         (target {FAST_TARGET:.1}x, simd_available={simd_available}, {threads} threads)"
    ));

    // --- dcgan32 train-step throughput across kernel modes ---
    let steps = if smoke { 6 } else { 40 };
    kernel::set_naive_mode(true);
    let naive_sps = train_steps_per_sec(steps, 41);
    kernel::set_naive_mode(false);
    kernel::set_threads(Some(1));
    let t1_sps = train_steps_per_sec(steps, 42);
    kernel::set_threads(None);
    let exact_sps = train_steps_per_sec(steps, 43);
    kernel::set_precision_mode(Some(KernelLane::Simd));
    let simd_sps = train_steps_per_sec(steps, 44);
    kernel::set_precision_mode(None);
    let train_speedup = exact_sps / naive_sps;
    let t1_speedup = t1_sps / naive_sps;
    let train_fast_speedup = simd_sps / exact_sps;
    let mut t = Table::new(
        "dcgan32 train-step throughput (sync, ref backend)",
        &["kernel mode", "steps/s", "vs naive"],
    );
    t.row(vec!["naive loops".into(), format!("{naive_sps:.2}"), "1.00x".into()]);
    t.row(vec![
        "exact, threads=1".into(),
        format!("{t1_sps:.2}"),
        format!("{t1_speedup:.2}x"),
    ]);
    t.row(vec![
        format!("exact, threads={threads}"),
        format!("{exact_sps:.2}"),
        format!("{train_speedup:.2}x"),
    ]);
    t.row(vec![
        format!("simd, threads={threads}"),
        format!("{simd_sps:.2}"),
        format!("{:.2}x", simd_sps / naive_sps),
    ]);
    rep.table(t);
    rep.note(format!(
        "train-step: exact {train_speedup:.2}x vs naive; simd lane {train_fast_speedup:.2}x vs exact"
    ));

    // --- BENCH_kernels.json (schema v2: per-shape naive/exact/simd) ---
    let json = obj(vec![
        ("format", js("paragan-bench-kernels")),
        ("version", num(2.0)),
        ("smoke", js(if smoke { "true" } else { "false" })),
        ("threads", num(threads as f64)),
        ("batch", num(REF_BATCH as f64)),
        ("simd_available", js(if simd_available { "true" } else { "false" })),
        ("fast_target", num(FAST_TARGET)),
        ("gemm", arr(gemm_rows)),
        ("gemm_total_speedup", num(gemm_speedup)),
        ("gemm_fast_vs_exact", num(fast_speedup)),
        (
            "train",
            obj(vec![
                ("model", js("dcgan32")),
                ("steps", num(steps as f64)),
                ("naive_steps_per_sec", num(naive_sps)),
                ("planned_t1_steps_per_sec", num(t1_sps)),
                ("exact_steps_per_sec", num(exact_sps)),
                ("simd_steps_per_sec", num(simd_sps)),
                ("t1_speedup", num(t1_speedup)),
                ("speedup", num(train_speedup)),
                ("fast_speedup", num(train_fast_speedup)),
            ]),
        ),
    ]);
    let mut text = String::new();
    write_json(&json, &mut text);
    text.push('\n');
    std::fs::write("BENCH_kernels.json", &text).expect("writing BENCH_kernels.json");
    rep.note("wrote BENCH_kernels.json");
    rep.finish();

    // CI gate 1: the exact engine must not lose to the naive loops over
    // the dcgan32 shape set.
    if exact_total_ns > naive_total_ns {
        eprintln!(
            "FAIL: exact-lane GEMM slower than naive over dcgan32 shapes \
             ({:.1} us vs {:.1} us)",
            exact_total_ns / 1e3,
            naive_total_ns / 1e3
        );
        std::process::exit(1);
    }
    // CI gate 2: on a SIMD-capable host, the fast lane must beat the exact
    // lane — by the recorded FAST_TARGET multiple on full runs, and at
    // least not lose on smoke runs (timings there are too short to hold a
    // multiple steady).  Non-SIMD hosts skip (the simd column degraded to
    // a second exact measurement).
    if simd_available {
        let floor = if smoke { 1.0 } else { FAST_TARGET };
        if fast_speedup < floor {
            eprintln!(
                "FAIL: fast lane {fast_speedup:.2}x over exact, below the \
                 {floor:.1}x gate over dcgan32 shapes \
                 ({:.1} us vs {:.1} us)",
                simd_total_ns / 1e3,
                exact_total_ns / 1e3
            );
            std::process::exit(1);
        }
    }
}
