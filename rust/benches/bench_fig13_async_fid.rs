//! cargo bench target regenerating the paper's Fig. 13 (async-update FID) —
//! REAL sync vs async training through the AOT artifacts.
use paragan::bench::Reporter;
use paragan::repro::{fig13, Fig13Config};

fn main() {
    let steps = std::env::var("PARAGAN_FIG13_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(60);
    let mut rep = Reporter::new("Fig. 13 — async vs sync update scheme (real training)");
    let cfg = Fig13Config { steps, eval_every: (steps / 4).max(1), ..Default::default() };
    match fig13(&cfg) {
        Ok((table, results)) => {
            rep.table(table);
            for (name, r) in &results {
                let fids: Vec<String> =
                    r.fid.points.iter().map(|p| format!("{}:{:.1}", p.step, p.value)).collect();
                rep.note(format!("{name} FID curve: {}", fids.join(" ")));
            }
            rep.note("paper: async converges faster early; sync wins at the end on hard tasks");
        }
        Err(e) => rep.note(format!("SKIPPED: {e} (run `make artifacts`)")),
    }
    rep.finish();
}
