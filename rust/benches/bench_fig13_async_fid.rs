//! cargo bench target regenerating the paper's Fig. 13 (async-update FID) —
//! REAL sync vs async training through the AOT artifacts.
use paragan::bench::Reporter;
use paragan::repro::{fig13, Fig13Config};

fn main() {
    let steps = std::env::var("PARAGAN_FIG13_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(60);
    let mut rep = Reporter::new("Fig. 13 — async vs sync update scheme (real training)");
    // Resolve sngan32 in the executable artifact set (ref conv artifacts on
    // a clean checkout) — unknown models are a hard error, not a skip.
    let (dir, model) = match paragan::testkit::artifacts_for("sngan32") {
        Ok(found) => found,
        Err(e) => {
            rep.note(format!("SKIPPED: {e}"));
            rep.finish();
            return;
        }
    };
    let cfg = Fig13Config {
        steps,
        eval_every: (steps / 4).max(1),
        artifact_dir: dir,
        model,
        ..Default::default()
    };
    match fig13(&cfg) {
        Ok((table, results)) => {
            rep.table(table);
            for (name, r) in &results {
                let fids: Vec<String> =
                    r.fid.points.iter().map(|p| format!("{}:{:.1}", p.step, p.value)).collect();
                rep.note(format!("{name} FID curve: {}", fids.join(" ")));
            }
            rep.note("paper: async converges faster early; sync wins at the end on hard tasks");
        }
        Err(e) => rep.note(format!("SKIPPED: {e} (run `make artifacts`)")),
    }
    rep.finish();
}
