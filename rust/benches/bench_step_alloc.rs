//! Step-allocation bench: the PR-5 workspace arena vs the allocating
//! baseline, plus the steady-state allocation counts the arena is gated on.
//!
//! Three measurements, written to `BENCH_step_alloc.json`:
//!
//! * **Throughput** — dcgan32 sync training steps/sec with the arena ON
//!   (default) vs `set_arena_mode(Some(false))` (the legacy allocating step
//!   path) at the all-core default thread count, plus 2-replica sync and
//!   async aggregate steps/sec with the arena on.
//! * **Steady-state allocations** — a counting global allocator measures N
//!   post-warmup steps of the fused 1-replica loop, the 2-replica sync loop
//!   (grads → buffer-reusing all-reduce → in-place apply), and the async
//!   fake-batch hand-off (ownership crossing the recycling `ImgBuff` +
//!   double-buffered `SnapshotCell`, two real threads).  All three are
//!   gated at ZERO since PR-7.
//!
//! Exit code 1 (the CI gate) if a gated count is nonzero or the arena loses
//! throughput to the allocating baseline.  `--test` runs the smoke-sized
//! protocol.
//!
//! Schema v3 (PR-9): the JSON carries a `phases` object — the per-phase
//! telemetry breakdown recorded during the arena-ON throughput run
//! (telemetry is reset right before it, after the preceding run's trainer
//! threads have joined).  The steady-state allocation counts above are
//! measured with recording at its default (ON), so they gate the
//! instrumented path — same contract as `tests/step_alloc.rs`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;

use paragan::coordinator::buffers::{ImgBuff, SnapshotCell, TaggedBatch};
use paragan::coordinator::trainer::{d_step_inputs_into, upsert_z};
use paragan::coordinator::{train_sync, TrainConfig};
use paragan::dist::{train_dist, DistConfig, DistMode, Exchange, InProcAllReduce, Topology};
use paragan::pipeline::Batch;
use paragan::runtime::{
    apply_step, refgen, run_inference_into, run_step_grads_into, run_step_into, set_arena_mode,
    ArtifactSpec, HostTensor, Manifest, ParamStore, Runtime, StepOutputs,
};
use paragan::util::json::{num, obj, s as js, write_json};
use paragan::util::rng::Rng;
use paragan::util::table::Table;

// --- counting allocator ---------------------------------------------------

struct CountingAlloc;
static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(p, l, n)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// --- fixtures -------------------------------------------------------------

fn small_batch_artifacts(batch: usize, tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("paragan-bench-step-alloc-{}-{tag}", std::process::id()));
    let models: Vec<refgen::RefModelSpec> = refgen::default_models()
        .into_iter()
        .filter(|m| m.name == "dcgan32")
        .collect();
    refgen::write_ref_artifacts_for(&dir, &models, batch).expect("dcgan32 export");
    dir
}

struct Rig {
    rt: Runtime,
    d_spec: ArtifactSpec,
    g_spec: ArtifactSpec,
    gen_spec: ArtifactSpec,
    d_params: ParamStore,
    d_slots: Vec<ParamStore>,
    g_params: ParamStore,
    g_slots: Vec<ParamStore>,
    d_in: BTreeMap<String, HostTensor>,
    g_in: BTreeMap<String, HostTensor>,
    gen_in: BTreeMap<String, HostTensor>,
    d_outs: StepOutputs,
    g_outs: StepOutputs,
    gen_outs: StepOutputs,
    rng: Rng,
    batch: usize,
    z_dim: usize,
}

fn rig(dir: &std::path::Path, seed: u64) -> Rig {
    let m = Manifest::load(dir).expect("manifest");
    let model = m.model("dcgan32").expect("dcgan32");
    let rt = Runtime::new(dir).expect("runtime");
    let mut rng = Rng::new(seed);
    let d_params = ParamStore::init(&model.params_d, &mut rng);
    let d_slots =
        ParamStore::init_slots(&model.params_d, &d_params, &model.optimizers["adam"].slot_init);
    let g_params = ParamStore::init(&model.params_g, &mut rng);
    let g_slots =
        ParamStore::init_slots(&model.params_g, &g_params, &model.optimizers["adam"].slot_init);
    let batch = model.batch;
    let mut shape = vec![batch];
    shape.extend_from_slice(&model.img_shape);
    let n: usize = shape.iter().product();
    let mut real = vec![0f32; n];
    rng.fill_gaussian(&mut real, 0.0, 0.5);
    let mut d_in = BTreeMap::new();
    d_in.insert("real".to_string(), HostTensor::new("real", shape.clone(), real));
    d_in.insert("fake".to_string(), HostTensor::new("fake", shape, vec![0f32; n]));
    Rig {
        d_spec: model.artifact("d_step_adam_fp32").unwrap().clone(),
        g_spec: model.artifact("g_step_adam_fp32").unwrap().clone(),
        gen_spec: model.artifact("generate_fp32").unwrap().clone(),
        rt,
        d_params,
        d_slots,
        g_params,
        g_slots,
        d_in,
        g_in: BTreeMap::new(),
        gen_in: BTreeMap::new(),
        d_outs: StepOutputs::new(),
        g_outs: StepOutputs::new(),
        gen_outs: StepOutputs::new(),
        rng,
        batch,
        z_dim: model.z_dim,
    }
}

impl Rig {
    fn fused_step(&mut self, step: u64) {
        upsert_z(&mut self.gen_in, &mut self.rng, self.batch, self.z_dim);
        run_inference_into(&self.rt, &self.gen_spec, &self.g_params, &self.gen_in, &mut self.gen_outs)
            .unwrap();
        let images = self.gen_outs.get_mut("images").unwrap();
        let fake = self.d_in.get_mut("fake").unwrap();
        std::mem::swap(&mut fake.data, &mut images.data);
        run_step_into(
            &self.rt,
            &self.d_spec,
            step as f32,
            2e-4,
            &mut self.d_params,
            &mut self.d_slots,
            None,
            &self.d_in,
            &mut self.d_outs,
        )
        .unwrap();
        upsert_z(&mut self.g_in, &mut self.rng, self.batch, self.z_dim);
        run_step_into(
            &self.rt,
            &self.g_spec,
            step as f32,
            2e-4,
            &mut self.g_params,
            &mut self.g_slots,
            Some(&self.d_params),
            &self.g_in,
            &mut self.g_outs,
        )
        .unwrap();
    }
}

/// Post-warmup allocation count of N fused steps on one replica.
fn fused_steady_allocs(dir: &std::path::Path, warmup: u64, measured: u64) -> u64 {
    let mut r = rig(dir, 0xA110C);
    for s in 1..=warmup {
        r.fused_step(s);
    }
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for s in warmup + 1..=warmup + measured {
        r.fused_step(s);
    }
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

fn reduce_scratch(
    ex: &dyn Exchange,
    replica: usize,
    grads: &mut ParamStore,
    scratch: &mut Vec<Vec<f32>>,
) {
    let matches = scratch.len() == grads.len()
        && scratch.iter().zip(grads.iter()).all(|(b, t)| b.len() == t.data.len());
    if matches {
        for (b, t) in scratch.iter_mut().zip(grads.iter()) {
            b.copy_from_slice(&t.data);
        }
    } else {
        scratch.clear();
        for t in grads.iter() {
            scratch.push(t.data.clone());
        }
    }
    ex.all_reduce_mean_into(replica, scratch).unwrap();
    for (t, b) in grads.iter_mut().zip(scratch.iter()) {
        t.data.copy_from_slice(b);
    }
}

/// Post-warmup allocation count of N grad-split steps across 2 lockstep
/// replicas (grads → all-reduce → apply), counted over BOTH threads.
fn sync2_steady_allocs(dir: &std::path::Path, warmup: u64, measured: u64) -> u64 {
    let n = 2usize;
    let ex_d = InProcAllReduce::new(n, Topology::Tree);
    let ex_g = InProcAllReduce::new(n, Topology::Tree);
    let warm = Barrier::new(n + 1);
    let start = Barrier::new(n + 1);
    let done = Barrier::new(n + 1);
    std::thread::scope(|s| {
        for r in 0..n {
            let dir = dir.to_path_buf();
            let (ex_d, ex_g) = (ex_d.clone(), ex_g.clone());
            let (warm, start, done) = (&warm, &start, &done);
            s.spawn(move || {
                let mut rg = rig(&dir, 0xD157);
                let mut shard = Rng::replica_stream(5, r as u64);
                let mut d_grads = ParamStore::new();
                let mut g_grads = ParamStore::new();
                let mut d_scratch: Vec<Vec<f32>> = Vec::new();
                let mut g_scratch: Vec<Vec<f32>> = Vec::new();
                let mut one = |rg: &mut Rig,
                               d_grads: &mut ParamStore,
                               g_grads: &mut ParamStore,
                               d_scratch: &mut Vec<Vec<f32>>,
                               g_scratch: &mut Vec<Vec<f32>>,
                               shard: &mut Rng,
                               step: u64| {
                    shard.fill_gaussian(&mut rg.d_in.get_mut("real").unwrap().data, 0.0, 0.5);
                    shard.fill_gaussian(&mut rg.d_in.get_mut("fake").unwrap().data, 0.0, 0.5);
                    run_step_grads_into(
                        &rg.rt,
                        &rg.d_spec,
                        &rg.d_params,
                        &rg.d_slots,
                        None,
                        &rg.d_in,
                        d_grads,
                        &mut rg.d_outs,
                    )
                    .unwrap();
                    reduce_scratch(ex_d.as_ref(), r, d_grads, d_scratch);
                    apply_step(
                        &rg.rt,
                        &rg.d_spec,
                        step as f32,
                        2e-4,
                        &mut rg.d_params,
                        &mut rg.d_slots,
                        d_grads,
                    )
                    .unwrap();
                    upsert_z(&mut rg.g_in, shard, rg.batch, rg.z_dim);
                    run_step_grads_into(
                        &rg.rt,
                        &rg.g_spec,
                        &rg.g_params,
                        &rg.g_slots,
                        Some(&rg.d_params),
                        &rg.g_in,
                        g_grads,
                        &mut rg.g_outs,
                    )
                    .unwrap();
                    reduce_scratch(ex_g.as_ref(), r, g_grads, g_scratch);
                    apply_step(
                        &rg.rt,
                        &rg.g_spec,
                        step as f32,
                        2e-4,
                        &mut rg.g_params,
                        &mut rg.g_slots,
                        g_grads,
                    )
                    .unwrap();
                };
                for s in 1..=warmup {
                    one(&mut rg, &mut d_grads, &mut g_grads, &mut d_scratch, &mut g_scratch, &mut shard, s);
                }
                warm.wait();
                start.wait();
                for s in warmup + 1..=warmup + measured {
                    one(&mut rg, &mut d_grads, &mut g_grads, &mut d_scratch, &mut g_scratch, &mut shard, s);
                }
                done.wait();
            });
        }
        warm.wait();
        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        start.wait();
        done.wait();
        COUNTING.store(false, Ordering::SeqCst);
    });
    ALLOCS.load(Ordering::SeqCst)
}

/// Post-warmup allocation count of N async G<->D rounds through the
/// recycling exchanges (free-list `ImgBuff` + double-buffered
/// `SnapshotCell`), counted over BOTH threads.  Lockstep rounds — one
/// produced batch, one D update, one snapshot publish — so the reader
/// provably releases its snapshot before the publisher laps it.
fn async_handoff_steady_allocs(dir: &std::path::Path, warmup: u64, measured: u64) -> u64 {
    let buff = ImgBuff::new(2);
    let cell = {
        let m = Manifest::load(dir).expect("manifest");
        let model = m.model("dcgan32").expect("dcgan32");
        let mut rng = Rng::new(0xD1A5);
        SnapshotCell::new(ParamStore::init(&model.params_d, &mut rng))
    };
    let warm = Barrier::new(3);
    let start = Barrier::new(3);
    let done = Barrier::new(3);
    let round = Barrier::new(2);
    std::thread::scope(|s| {
        // G side (replica 0): step against the latest snapshot, ship fakes
        // in recycled shells.
        {
            let dir = dir.to_path_buf();
            let (buff, cell) = (buff.clone(), cell.clone());
            let (warm, start, done, round) = (&warm, &start, &done, &round);
            s.spawn(move || {
                let _bind = paragan::runtime::bind_replica(0);
                let mut rg = rig(&dir, 0x6A11);
                let mut one = |rg: &mut Rig, r: u64| {
                    let (d_snap, _) = cell.latest();
                    upsert_z(&mut rg.g_in, &mut rg.rng, rg.batch, rg.z_dim);
                    run_step_into(
                        &rg.rt,
                        &rg.g_spec,
                        r as f32,
                        2e-4,
                        &mut rg.g_params,
                        &mut rg.g_slots,
                        Some(&d_snap),
                        &rg.g_in,
                        &mut rg.g_outs,
                    )
                    .unwrap();
                    drop(d_snap);
                    let mut b = buff.take_recycled().unwrap_or_else(TaggedBatch::empty);
                    b.refill_from(rg.g_outs.get_mut("fake").unwrap(), rg.g_in.get("y"), r);
                    assert!(buff.push(b));
                    round.wait();
                };
                for r in 1..=warmup {
                    one(&mut rg, r);
                }
                warm.wait();
                start.wait();
                for r in warmup + 1..=warmup + measured {
                    one(&mut rg, r);
                }
                done.wait();
            });
        }
        // D side (replica 1): consume, update, publish by refilling the
        // retired snapshot, recycle the shell.
        {
            let dir = dir.to_path_buf();
            let (buff, cell) = (buff.clone(), cell.clone());
            let (warm, start, done, round) = (&warm, &start, &done, &round);
            s.spawn(move || {
                let _bind = paragan::runtime::bind_replica(1);
                let m = Manifest::load(&dir).expect("manifest");
                let model = m.model("dcgan32").expect("dcgan32");
                let img_shape = model.img_shape.clone();
                let n_classes = model.n_classes;
                let mut rg = rig(&dir, 0xD1A5);
                let mut shard = Rng::replica_stream(7, 1);
                let numel: usize = rg.batch * img_shape.iter().product::<usize>();
                let mut real = Batch {
                    data: vec![0f32; numel],
                    labels: vec![0u32; rg.batch],
                    batch_size: rg.batch,
                };
                let mut one = |rg: &mut Rig, real: &mut Batch, shard: &mut Rng, r: u64| {
                    let fake = buff.pop_batch().unwrap();
                    shard.fill_gaussian(&mut real.data, 0.0, 0.5);
                    d_step_inputs_into(&mut rg.d_in, real, &img_shape, n_classes, &fake)
                        .unwrap();
                    run_step_into(
                        &rg.rt,
                        &rg.d_spec,
                        r as f32,
                        2e-4,
                        &mut rg.d_params,
                        &mut rg.d_slots,
                        None,
                        &rg.d_in,
                        &mut rg.d_outs,
                    )
                    .unwrap();
                    cell.publish_with(
                        r,
                        |ps| ps.copy_values_from(&rg.d_params).unwrap(),
                        || rg.d_params.snapshot(),
                    );
                    buff.recycle(fake);
                    round.wait();
                };
                for r in 1..=warmup {
                    one(&mut rg, &mut real, &mut shard, r);
                }
                warm.wait();
                start.wait();
                for r in warmup + 1..=warmup + measured {
                    one(&mut rg, &mut real, &mut shard, r);
                }
                done.wait();
            });
        }
        warm.wait();
        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        start.wait();
        done.wait();
        COUNTING.store(false, Ordering::SeqCst);
    });
    ALLOCS.load(Ordering::SeqCst)
}

fn train_steps_per_sec(steps: u64, seed: u64) -> f64 {
    let (dir, model) = paragan::testkit::artifacts_for("dcgan32").expect("dcgan32 artifacts");
    let cfg = TrainConfig {
        artifact_dir: dir,
        model,
        steps,
        seed,
        eval_batches: 2,
        log_every: 0,
        ..Default::default()
    };
    train_sync(&cfg).expect("dcgan32 train run").steps_per_sec()
}

fn dist_steps_per_sec(steps: u64, seed: u64, replicas: usize, mode: DistMode) -> f64 {
    let (dir, model) = paragan::testkit::artifacts_for("dcgan32").expect("dcgan32 artifacts");
    let cfg = TrainConfig {
        artifact_dir: dir,
        model,
        steps,
        seed,
        eval_batches: 2,
        log_every: 0,
        replicas,
        dist: DistConfig { mode, ..Default::default() },
        ..Default::default()
    };
    train_dist(&cfg).expect("dcgan32 dist run").aggregate_steps_per_sec
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let (warmup, measured) = (2u64, if smoke { 2u64 } else { 4 });
    let steps = if smoke { 6 } else { 40 };
    let alloc_batch = if smoke { 4 } else { 8 };
    println!(
        "== step-alloc bench{} ==",
        if smoke { " (smoke)" } else { "" }
    );

    // --- steady-state allocation counts (small-batch export: the counts
    // are shape-independent, only the warmup wall-clock isn't) ---
    let dir = small_batch_artifacts(alloc_batch, "counts");
    let fused_allocs = fused_steady_allocs(&dir, warmup, measured);
    let sync2_allocs = sync2_steady_allocs(&dir, warmup, measured);
    let async_allocs = async_handoff_steady_allocs(&dir, warmup, measured);

    // --- throughput: arena vs allocating baseline (all-core) ---
    set_arena_mode(Some(false));
    let baseline_sps = train_steps_per_sec(steps, 41);
    set_arena_mode(Some(true));
    // Phase breakdown for the arena run: reset is safe here — the baseline
    // run's trainer thread (the only ring writer so far) has returned.
    paragan::telemetry::reset();
    let arena_sps = train_steps_per_sec(steps, 41);
    let phases = paragan::telemetry::report().phases_json();
    set_arena_mode(None);
    let speedup = arena_sps / baseline_sps.max(1e-12);

    // --- dist throughput with the arena (context series for BENCH_dist) ---
    let sync2_sps = dist_steps_per_sec(steps.min(12), 43, 2, DistMode::Sync);
    let async2_sps = dist_steps_per_sec(steps.min(12), 44, 2, DistMode::Async);

    let mut t = Table::new(
        "dcgan32 step path: workspace arena vs allocating baseline",
        &["metric", "value"],
    );
    t.row(vec!["fused steady-state allocs (1 replica)".into(), fused_allocs.to_string()]);
    t.row(vec!["grad-split steady-state allocs (2-replica sync)".into(), sync2_allocs.to_string()]);
    t.row(vec!["async fake hand-off steady-state allocs".into(), async_allocs.to_string()]);
    t.row(vec!["baseline steps/s (arena off)".into(), format!("{baseline_sps:.2}")]);
    t.row(vec!["arena steps/s".into(), format!("{arena_sps:.2}")]);
    t.row(vec!["speedup".into(), format!("{speedup:.2}x")]);
    t.row(vec!["2-replica sync agg steps/s".into(), format!("{sync2_sps:.2}")]);
    t.row(vec!["2-replica async agg steps/s".into(), format!("{async2_sps:.2}")]);
    println!("{}", t.render());

    let json = obj(vec![
        ("format", js("paragan-bench-step-alloc")),
        ("version", num(3.0)),
        ("smoke", js(if smoke { "true" } else { "false" })),
        ("model", js("dcgan32")),
        ("warmup_steps", num(warmup as f64)),
        ("measured_steps", num(measured as f64)),
        ("fused_steady_allocs", num(fused_allocs as f64)),
        ("sync2_steady_allocs", num(sync2_allocs as f64)),
        ("async_handoff_steady_allocs", num(async_allocs as f64)),
        ("baseline_steps_per_sec", num(baseline_sps)),
        ("arena_steps_per_sec", num(arena_sps)),
        ("speedup", num(speedup)),
        ("target_speedup", num(1.15)),
        ("meets_target", js(if speedup >= 1.15 { "true" } else { "false" })),
        ("sync2_agg_steps_per_sec", num(sync2_sps)),
        ("async2_agg_steps_per_sec", num(async2_sps)),
        ("phases", phases),
    ]);
    let mut text = String::new();
    write_json(&json, &mut text);
    text.push('\n');
    std::fs::write("BENCH_step_alloc.json", &text).expect("writing BENCH_step_alloc.json");
    println!("wrote BENCH_step_alloc.json");

    // CI gates: the steady state must be allocation-free and the arena must
    // not lose to the allocating baseline.
    let mut failed = false;
    if fused_allocs != 0 {
        eprintln!("FAIL: fused steady-state step path allocated {fused_allocs} times");
        failed = true;
    }
    if sync2_allocs != 0 {
        eprintln!("FAIL: 2-replica sync steady-state path allocated {sync2_allocs} times");
        failed = true;
    }
    if async_allocs != 0 {
        eprintln!("FAIL: async fake hand-off steady state allocated {async_allocs} times");
        failed = true;
    }
    if speedup < 1.0 {
        eprintln!(
            "FAIL: arena steps/sec ({arena_sps:.2}) loses to the allocating \
             baseline ({baseline_sps:.2})"
        );
        failed = true;
    }
    if speedup < 1.15 {
        eprintln!(
            "note: speedup {speedup:.2}x below the 1.15x target (recorded, \
             gated only on parity with the baseline)"
        );
    }
    if failed {
        std::process::exit(1);
    }
}
