//! cargo bench target regenerating the paper's Fig. 4 — operator usage profile at scale (see repro::fig4).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Fig. 4 — operator usage profile at scale");
    let (table, _) = paragan::repro::fig4(16, 300);
    rep.table(table);
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("fig4 (simulator sweep)", &cfg, || {
        let _ = paragan::repro::fig4(16, 60);
    }));
    rep.note("paper: idle grows ~13.6% from 8 to 1024 workers; conv still dominates");
    rep.finish();
}
