//! cargo bench target regenerating the paper's Table 2 (system ablation).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Table 2 — ablation of system optimizations");
    let (table, _) = paragan::repro::table2(300);
    rep.table(table);
    rep.table(paragan::repro::table1(200));
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("table2 (simulator ladder)", &cfg, || {
        let _ = paragan::repro::table2(60);
    }));
    rep.note("paper ladder: 6459 -> 7158 (+10.8%) -> 7412 (+3.9%) -> 8539 (+15.2%) img/s");
    rep.finish();
}
