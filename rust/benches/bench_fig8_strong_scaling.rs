//! cargo bench target regenerating the paper's Fig. 8 (strong scaling).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Fig. 8 — strong scaling, total batch 512");
    let (table, _) = paragan::repro::fig8(300);
    rep.table(table);
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("fig8 (simulator sweep)", &cfg, || {
        let _ = paragan::repro::fig8(60);
    }));
    rep.note("paper: time-to-solution 30h -> 3h; img/s saturates past 128 workers");
    rep.finish();
}
