//! cargo bench target regenerating the paper's Fig. 11 (pipeline latency) —
//! measured on the REAL rust pipeline with injected congestion.
use paragan::bench::Reporter;
use paragan::repro::{fig11, Fig11Config};

fn main() {
    let mut rep = Reporter::new("Fig. 11 — data pipeline latency under congestion");
    let cfg = Fig11Config::default();
    let (table, res) = fig11(&cfg);
    rep.table(table);
    rep.note(format!(
        "tuner grew {} times, final prefetch workers {}",
        res.tuned_grows, res.tuned_final_workers
    ));
    rep.note("paper: 'our pipeline tuner has a lower variance in latency'");
    rep.finish();
}
