//! cargo bench target regenerating the paper's Fig. 7 — framework/hardware throughput (see repro::fig7).
use paragan::bench::{bench, BenchConfig, Reporter};

fn main() {
    let mut rep = Reporter::new("Fig. 7 — framework/hardware throughput");
    let (table, _) = paragan::repro::fig7(16, 300);
    rep.table(table);
    let cfg = BenchConfig { min_iters: 5, max_iters: 20, ..Default::default() };
    rep.add(bench("fig7 (simulator sweep)", &cfg, || {
        let _ = paragan::repro::fig7(16, 60);
    }));
    rep.note("paper: ParaGAN > StudioGAN > TF on 8xV100; larger gap on TPU");
    rep.finish();
}
