//! Dist scaling bench: REAL multi-replica dcgan32 training at 1/2/4/8
//! replicas, sync (all-reduce) vs async (parameter server), measured on the
//! ref backend and compared against the fig9 cluster simulator's
//! weak-scaling prediction for the same worker counts.  Writes
//! `BENCH_dist.json` next to `BENCH_kernels.json`.
//!
//! Per-replica GEMM threads are pinned to 1 so the replica count is the
//! ONLY parallelism axis being measured (otherwise the 1-replica baseline
//! grabs every core and the comparison measures scheduler contention, not
//! scaling).  In-process replicas share one host's cores, so measured
//! efficiency at replica counts beyond the core count degrades by
//! construction — the simulator models a pod where every worker owns its
//! chip; the delta between the two is exactly what the fig9 cross-check
//! (`repro::fig9_crosscheck`) reports.
//!
//! `--test` runs the smoke protocol (1/2 replicas, tiny step budget) — the
//! CI gate: sync multi-replica aggregate steps/sec must beat the 1-replica
//! baseline; every async run's mean applied-update staleness must respect
//! the parameter-server bound (defense-in-depth — the trainer itself
//! hard-errors on violation); and every mdgan run's mean fake-batch
//! staleness must respect its queue-capacity backpressure bound.
//!
//! Schema v2 (PR-9): each run row carries a `phases` object — the
//! per-phase telemetry breakdown (count / total / mean / p50 / p95 / p99)
//! recorded during THAT run; telemetry is reset between runs (quiescent:
//! `train_dist` joins every replica thread before returning).
//!
//! Schema v3 (PR-10): the sync sweep runs each replica count TWICE — serial
//! oracle (`overlap = off`) and the bucketized overlap lane — and every run
//! row gains an `overlap` object: `enabled`, total + p95 EXPOSED exchange
//! wait (`exchange_wait`, the worker parked at the barrier / finish tail),
//! total communicator BUSY time (`bucket_exchange`), and `hidden_pct` =
//! the share of communicator busy time hidden under backward compute.
//! Gate: at every multi-replica sync count the overlapped lane's aggregate
//! steps/sec must stay within jitter (≥ 95%) of the serial lane — overlap
//! must never cost throughput.

use paragan::coordinator::TrainConfig;
use paragan::dist::{train_dist, DistMode, DistResult};
use paragan::repro::simulated_dcgan32_efficiency;
use paragan::util::json::{arr, num, obj, s as js, write_json, Json};
use paragan::util::table::{f2, pct, Table};

const STALENESS_BOUND: u64 = 2;

/// One measured run, plus the per-phase telemetry breakdown and the v3
/// overlap block it recorded.  `overlap = None` leaves the lane at the
/// run-level default (the `PARAGAN_OVERLAP` env rule).
fn run(
    mode: DistMode,
    replicas: usize,
    steps: u64,
    overlap: Option<bool>,
) -> (DistResult, Json, Json, f64) {
    let (dir, model) = paragan::testkit::artifacts_for("dcgan32").expect("dcgan32 artifacts");
    let cfg = TrainConfig {
        artifact_dir: dir,
        model,
        steps,
        seed: 42,
        eval_batches: 2,
        log_every: 0,
        threads: Some(1), // one GEMM worker per replica: replicas ARE the parallelism
        replicas,
        dist: paragan::dist::DistConfig {
            mode,
            staleness_bound: STALENESS_BOUND,
            overlap,
            ..Default::default()
        },
        ..Default::default()
    };
    // Quiescent between runs: `train_dist` joins every replica thread
    // before returning, so the reset never races a recorder.
    paragan::telemetry::reset();
    let r = train_dist(&cfg).unwrap_or_else(|e| panic!("{} x{replicas}: {e:?}", mode.as_str()));
    let rep = paragan::telemetry::report();
    let stat = |name: &str| rep.phases.iter().find(|p| p.phase.name() == name);
    // EXPOSED wait: the worker parked at the serial barrier, or at the
    // overlapped finish tail.  BUSY: communicator time inside bucket
    // rounds (and async push calls).  hidden = busy the worker never saw.
    let (wait_secs, wait_p95) =
        stat("exchange_wait").map(|p| (p.total_secs, p.p95_us)).unwrap_or((0.0, 0.0));
    let busy_secs = stat("bucket_exchange").map(|p| p.total_secs).unwrap_or(0.0);
    let hidden_pct = if busy_secs > 0.0 {
        100.0 * (busy_secs - wait_secs).max(0.0) / busy_secs
    } else {
        0.0
    };
    let enabled = cfg.dist.overlap_enabled() && mode != DistMode::MdGan;
    let ov = obj(vec![
        ("enabled", js(if enabled { "true" } else { "false" })),
        ("exchange_wait_secs", num(wait_secs)),
        ("exchange_wait_p95_us", num(wait_p95)),
        ("bucket_exchange_secs", num(busy_secs)),
        ("hidden_pct", num(hidden_pct)),
    ]);
    (r, rep.phases_json(), ov, hidden_pct)
}

/// Weak-scaling efficiency vs the 1-replica sync baseline: per-replica
/// aggregate throughput retained.
fn efficiency(base: &DistResult, r: &DistResult) -> f64 {
    (r.aggregate_steps_per_sec / r.replicas as f64)
        / (base.aggregate_steps_per_sec / base.replicas.max(1) as f64)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let steps: u64 = if smoke { 4 } else { 24 };
    let sync_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    // async/mdgan need both a G and a D side.
    let par_counts: &[usize] = if smoke { &[2] } else { &[2, 4, 8] };

    let mut t = Table::new(
        if smoke {
            "dist scaling — dcgan32, ref backend (smoke)"
        } else {
            "dist scaling — dcgan32, ref backend"
        },
        &[
            "mode",
            "replicas",
            "overlap",
            "hidden%",
            "agg steps/s",
            "efficiency",
            "sim eff",
            "staleness",
            "drops",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut base: Option<DistResult> = None;
    let mut gate_failures: Vec<String> = Vec::new();

    let mut record = |mode: DistMode,
                      ov_label: &str,
                      r: DistResult,
                      phases: Json,
                      ov: Json,
                      hidden_pct: f64,
                      base: &Option<DistResult>| {
        let eff = base.as_ref().map(|b| efficiency(b, &r)).unwrap_or(1.0);
        let sim_eff = if r.replicas >= 2 && mode == DistMode::Sync {
            simulated_dcgan32_efficiency(r.replicas, 8, if smoke { 80 } else { 150 })
        } else {
            f64::NAN
        };
        t.row(vec![
            mode.as_str().into(),
            r.replicas.to_string(),
            ov_label.to_string(),
            if ov_label == "off" { "-".into() } else { format!("{hidden_pct:.0}%") },
            f2(r.aggregate_steps_per_sec),
            pct(eff),
            if sim_eff.is_nan() { "-".into() } else { pct(sim_eff) },
            f2(r.train.mean_staleness),
            r.stale_drops.to_string(),
        ]);
        rows.push(obj(vec![
            ("mode", js(mode.as_str())),
            ("replicas", num(r.replicas as f64)),
            ("overlap", ov),
            ("steps", num(r.train.steps as f64)),
            ("wall_secs", num(r.train.wall_secs)),
            ("steps_per_sec", num(r.train.steps_per_sec())),
            ("aggregate_steps_per_sec", num(r.aggregate_steps_per_sec)),
            ("images_per_sec", num(r.train.images_per_sec())),
            ("efficiency", num(eff)),
            ("sim_efficiency", num(if sim_eff.is_nan() { -1.0 } else { sim_eff })),
            ("mean_staleness", num(r.train.mean_staleness)),
            ("mean_fake_staleness", num(r.mean_fake_staleness)),
            ("staleness_bound", num(STALENESS_BOUND as f64)),
            ("stale_drops", num(r.stale_drops as f64)),
            ("swaps", num(r.swaps as f64)),
            ("replica_steps", num(r.replica_steps as f64)),
            ("phases", phases),
        ]));
        r
    };

    // --- sync sweep (the weak-scaling curve; n=1 serial is the baseline;
    // every multi-replica count runs serial AND overlapped, v3 gate) ---
    for &n in sync_counts {
        let (r, phases, ov, hp) = run(DistMode::Sync, n, steps, Some(false));
        let serial_agg = r.aggregate_steps_per_sec;
        let r = record(DistMode::Sync, "off", r, phases, ov, hp, &base);
        if base.is_none() {
            base = Some(r);
        } else if n > 1 {
            let b = base.as_ref().unwrap();
            if r.aggregate_steps_per_sec <= b.aggregate_steps_per_sec {
                gate_failures.push(format!(
                    "sync {n}-replica aggregate {:.2} steps/s does not beat the \
                     1-replica baseline {:.2}",
                    r.aggregate_steps_per_sec, b.aggregate_steps_per_sec
                ));
            }
        }
        if n > 1 {
            let (r, phases, ov, hp) = run(DistMode::Sync, n, steps, Some(true));
            // Overlap may hide exchange wait but must never COST
            // throughput; 5% grace absorbs shared-host timing jitter.
            if r.aggregate_steps_per_sec < 0.95 * serial_agg {
                gate_failures.push(format!(
                    "sync {n}-replica overlapped aggregate {:.2} steps/s fell below \
                     the serial lane's {serial_agg:.2} (jitter grace 5%)",
                    r.aggregate_steps_per_sec
                ));
            }
            record(DistMode::Sync, "on", r, phases, ov, hp, &base);
        }
    }

    // --- async (parameter server) and mdgan sweeps ---
    let queue_cap = TrainConfig::default().img_buff_cap as f64;
    for mode in [DistMode::Async, DistMode::MdGan] {
        for &n in par_counts {
            // Async G workers use the overlapped push lane (pinned on so the
            // row is env-independent); mdgan has no exchange lane to overlap
            // — see the ROADMAP PR-10 decision.
            let overlap = if mode == DistMode::Async { Some(true) } else { None };
            let label = if mode == DistMode::Async { "on" } else { "off" };
            let (r, phases, ov, hp) = run(mode, n, steps, overlap);
            if mode == DistMode::Async && r.train.mean_staleness > STALENESS_BOUND as f64 {
                gate_failures.push(format!(
                    "async {n}-replica mean staleness {:.2} exceeds bound {STALENESS_BOUND}",
                    r.train.mean_staleness
                ));
            }
            // mdgan's staleness bound is the per-D task-queue capacity: G's
            // blocking send caps how far a queued fake batch can age.
            if mode == DistMode::MdGan && r.mean_fake_staleness > queue_cap {
                gate_failures.push(format!(
                    "mdgan {n}-replica mean fake staleness {:.2} exceeds queue cap {queue_cap}",
                    r.mean_fake_staleness
                ));
            }
            record(mode, label, r, phases, ov, hp, &base);
        }
    }
    drop(record);

    println!("{}", t.render());

    let json = obj(vec![
        ("format", js("paragan-bench-dist")),
        ("version", num(3.0)),
        ("smoke", js(if smoke { "true" } else { "false" })),
        ("model", js("dcgan32")),
        ("batch", num(paragan::runtime::refgen::REF_BATCH as f64)),
        ("threads_per_replica", num(1.0)),
        ("steps", num(steps as f64)),
        ("runs", arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&json, &mut text);
    text.push('\n');
    std::fs::write("BENCH_dist.json", &text).expect("writing BENCH_dist.json");
    println!("wrote BENCH_dist.json");

    if let Some(xcheck) =
        paragan::repro::fig9_crosscheck(std::path::Path::new("BENCH_dist.json"))
    {
        println!("{}", xcheck.render());
    }

    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
}
