//! Quickstart: train a small GAN end-to-end through the three-layer stack
//! (rust coordinator -> PJRT -> AOT'd JAX/Pallas HLO) in ~a minute.
//!
//!     make artifacts && cargo run --release --example quickstart
use paragan::coordinator::OptimizationPolicy;
use paragan::gan::{Estimator, UpdateScheme};
use paragan::metrics::tracker::sparkline;

fn main() -> anyhow::Result<()> {
    // Listing-1-shaped API: pick a backbone, a policy, train.
    let result = Estimator::new("dcgan32")
        .artifact_dir("artifacts")
        .policy(OptimizationPolicy::paper_asymmetric()) // AdaBelief(G) + Adam(D)
        .scheme(UpdateScheme::Sync)
        .steps(40)
        .eval_every(20)
        .eval_batches(2)
        .log_every(10)
        .train()?;

    let g: Vec<f64> = result.g_loss.downsample(40).iter().map(|p| p.value).collect();
    let d: Vec<f64> = result.d_loss.downsample(40).iter().map(|p| p.value).collect();
    println!("\n== quickstart: dcgan32, 40 steps ==");
    println!("g_loss {}  last {:.4}", sparkline(&g), result.g_loss.last().unwrap());
    println!("d_loss {}  last {:.4}", sparkline(&d), result.d_loss.last().unwrap());
    println!("FID-proxy {:.2}  mode coverage {:.2}", result.final_fid(),
        result.mode_cov.last().unwrap_or(f64::NAN));
    println!("throughput: {:.2} steps/s, {:.1} img/s", result.steps_per_sec(), result.images_per_sec());
    Ok(())
}
