//! `cargo xtask` — the repo's zero-dependency task runner (aliased in
//! .cargo/config.toml).
//!
//! Commands:
//! * `cargo xtask lint [root]` — run the paragan-lint conventions pass over
//!   `rust/src` (or an explicit root).  Exit 1 with `file:line` diagnostics
//!   on any violation; see `src/lint.rs` for the rule set and
//!   `lint_allow.txt` for the (reviewable) suppression list.

mod lint;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn workspace_root() -> PathBuf {
    // xtask lives at <root>/xtask; the manifest dir is compile-time known.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent dir").to_path_buf()
}

fn run_lint(root_arg: Option<&str>) -> ExitCode {
    let ws = workspace_root();
    let root = match root_arg {
        Some(p) => PathBuf::from(p),
        None => ws.join("rust/src"),
    };
    let allow_path = ws.join("xtask/lint_allow.txt");
    let allow = std::fs::read_to_string(&allow_path)
        .map(|t| lint::parse_allowlist(&t))
        .unwrap_or_default();
    match lint::lint_tree(&root, &allow) {
        Ok(viols) if viols.is_empty() => {
            println!("paragan-lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(viols) => {
            for v in &viols {
                eprintln!("{v}");
            }
            eprintln!("paragan-lint: {} violation(s)", viols.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("paragan-lint: cannot read {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(args.get(1).map(String::as_str)),
        Some(other) => {
            eprintln!("unknown xtask command '{other}' (available: lint)");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint [root]");
            ExitCode::FAILURE
        }
    }
}
