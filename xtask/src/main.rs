//! `cargo xtask` — the repo's zero-dependency task runner (aliased in
//! .cargo/config.toml).
//!
//! Commands:
//! * `cargo xtask lint [root]` — run the paragan-lint conventions pass:
//!   the full rule set over `rust/src` (or an explicit root), plus the
//!   cross-cutting `bare-sync` rule over the test/bench/example/xtask
//!   trees (default invocation only).  Exit 1 with `file:line` diagnostics
//!   on any violation; see `src/lint.rs` for the rule set and
//!   `lint_allow.txt` for the (reviewable) suppression list.

mod lint;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn workspace_root() -> PathBuf {
    // xtask lives at <root>/xtask; the manifest dir is compile-time known.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent dir").to_path_buf()
}

fn run_lint(root_arg: Option<&str>) -> ExitCode {
    let ws = workspace_root();
    let root = match root_arg {
        Some(p) => PathBuf::from(p),
        None => ws.join("rust/src"),
    };
    let allow_path = ws.join("xtask/lint_allow.txt");
    let allow = std::fs::read_to_string(&allow_path)
        .map(|t| lint::parse_allowlist(&t))
        .unwrap_or_default();
    let result = lint::lint_tree(&root, &allow).and_then(|mut viols| {
        // Default invocation also sweeps the workspace's other source trees
        // with the cross-cutting bare-sync rule (tests and benches must use
        // the `util::sync` shim too, or they fall out of loom coverage).
        if root_arg.is_none() {
            for tree in ["rust/tests", "rust/benches", "rust/examples", "xtask/src"] {
                let t = ws.join(tree);
                if t.is_dir() {
                    viols.extend(lint::lint_tree_rules(&t, &allow, &["bare-sync"])?);
                }
            }
        }
        Ok(viols)
    });
    match result {
        Ok(viols) if viols.is_empty() => {
            println!("paragan-lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(viols) => {
            for v in &viols {
                eprintln!("{v}");
            }
            eprintln!("paragan-lint: {} violation(s)", viols.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("paragan-lint: cannot read {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(args.get(1).map(String::as_str)),
        Some(other) => {
            eprintln!("unknown xtask command '{other}' (available: lint)");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint [root]");
            ExitCode::FAILURE
        }
    }
}
