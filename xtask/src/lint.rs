//! `paragan-lint`: a text-level lint that turns the ROADMAP decision log
//! into CI-enforceable rules.  Zero dependencies (no syn in the offline
//! vendor set), so it works on stripped source lines — a per-line scanner
//! that blanks string literals and separates comments, plus a brace counter
//! for function bodies.  That is deliberately cruder than an AST walk, and
//! exactly as precise as these rules need:
//!
//! * **unsafe-safety** — every `unsafe` in code carries a `// SAFETY:`
//!   comment on the same line or the immediately preceding comment block.
//! * **hot-alloc** — functions on the zero-allocation steady-state path
//!   (names ending `_ws` / `_into` / `_in_place`, plus the GEMM
//!   `micro_tile`) contain no allocation tokens (`vec!`,
//!   `Vec::with_capacity`, `.to_vec()`, `.to_owned()`, `Box::new(`,
//!   `.clone(`).  Warmup / overflow / fallback lanes are annotated at the
//!   allocation site with `// alloc-ok: <reason>` (covers the line and the
//!   next 3 lines below it).  Cold error paths (`format!` inside
//!   `bail!`/`with_context`) are outside the token set by design: an error
//!   tears the run down, so its allocations never recur in steady state.
//! * **tile-const** — tile/blocking and lane-selection constants (`MR`,
//!   `NR`, `MC`, `NC`, `KC`, `KU` (K-chain depth), `LANES` (vector width),
//!   `TILE[S]`, `BLOCK[S]`, `BUCKET[S]` (gradient-exchange bucket sizing)
//!   name segments) may only be declared in `layout/plan.rs`: kernels and
//!   exchange lanes receive sizes from the layout planner, they never
//!   compute them (ROADMAP PR-3/PR-5/PR-8/PR-10 decisions).
//! * **kernel-purity** — kernel / workspace / planner modules contain no
//!   timing or thread-management calls (`Instant::now`, `SystemTime::now`,
//!   `thread::spawn`, `thread::sleep`): kernels compute, the exec layer
//!   schedules, benches time.
//! * **telemetry-purity** — the same kernel / workspace / planner modules
//!   contain no `telemetry::` references either: instrumentation lives at
//!   the boundary layers (`runtime/step.rs`, `coordinator/*`, `dist/*`,
//!   `pipeline/*`).  Pure modules expose plain atomic counters (the
//!   kernel's SIMD degrade count, the workspace's overflow takes) that the
//!   telemetry report MIRRORS at read time — the PR-9 boundary discipline.
//! * **exchange-combine** — in any file implementing `Exchange`, the
//!   `all_reduce_mean` / `all_reduce_mean_into` bodies must route through
//!   the fixed-order `combine` helpers (or forward to
//!   `self.all_reduce_mean`): the deterministic combine order is the PR-4
//!   convention that makes sync training bit-reproducible.
//! * **bare-sync** — `std::sync::Mutex` / `Condvar` / `MutexGuard` may be
//!   named only in `util/sync.rs` (the loom shim).  Everywhere else,
//!   lock/condvar primitives come through `crate::util::sync` so the loom
//!   lane (`--cfg loom`) can model-check every handoff — the PR-6 binding
//!   convention.  `std::sync::{Arc, Barrier, mpsc, atomic}` have no loom
//!   substitution requirement here and stay allowed.  Unlike the
//!   path-scoped rules above, this one also runs over the test/bench/
//!   example/xtask trees (see `lint_tree_rules`).
//!
//! Suppressions beyond the inline escapes live in `xtask/lint_allow.txt`
//! (`<rule> <file-suffix>` per line) so every exception is reviewable in
//! one place.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

const HOT_SUFFIXES: [&str; 3] = ["_ws", "_into", "_in_place"];
const HOT_NAMES: [&str; 4] =
    ["micro_tile", "micro_tile_fast", "micro_tile_fast_body", "micro_tile_fast_x86"];
const ALLOC_TOKENS: [&str; 6] =
    ["vec!", "Vec::with_capacity", ".to_vec()", ".to_owned()", "Box::new(", ".clone("];
const TILE_SEGMENTS: [&str; 13] = [
    "MR", "NR", "MC", "NC", "KC", "KU", "LANES", "TILE", "TILES", "BLOCK", "BLOCKS", "BUCKET",
    "BUCKETS",
];
/// The one file allowed to define tile/blocking constants.
const TILE_HOME: &str = "layout/plan.rs";
const PURITY_FILES: [&str; 4] =
    ["runtime/kernel.rs", "runtime/ref_conv.rs", "runtime/workspace.rs", "layout/plan.rs"];
const PURITY_TOKENS: [&str; 4] =
    ["Instant::now", "SystemTime::now", "thread::spawn", "thread::sleep"];
/// Telemetry is a boundary-layer concern: recording this token in a purity
/// file means a pure module grew an observability dependency (PR-9).
const TELEMETRY_TOKEN: &str = "telemetry::";
/// The one module allowed to name `std::sync` lock primitives: the shim
/// that swaps them for loom's under `--cfg loom`.
const SYNC_HOME: &str = "util/sync.rs";
const BARE_SYNC_TYPES: [&str; 3] = ["Mutex", "Condvar", "MutexGuard"];
/// How many comment/attribute/blank lines above an `unsafe` the SAFETY
/// comment may start.
const SAFETY_LOOKBACK: usize = 10;
/// How many lines below an `// alloc-ok:` marker it covers.
const ALLOC_OK_REACH: usize = 3;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Path as reported (relative to the lint root).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One source line split into its code and comment parts, string literals
/// blanked out of the code.
struct SplitLine {
    code: String,
    comment: String,
}

/// Split `line` into code/comment, carrying block-comment state across
/// lines.  String literals are replaced by `""` so tokens inside them never
/// match; char literals are skipped (distinguished from lifetimes by their
/// closing quote).  Raw-string hashes and multi-line strings degrade to
/// per-line scanning — acceptable for a convention lint (the tree-clean
/// test below keeps false positives at zero for this repo).
fn split_line(line: &str, in_block_comment: &mut bool) -> SplitLine {
    let b = line.as_bytes();
    let mut code = String::with_capacity(line.len());
    let mut comment = String::new();
    let mut i = 0;
    while i < b.len() {
        if *in_block_comment {
            match line[i..].find("*/") {
                Some(j) => {
                    comment.push_str(&line[i..i + j]);
                    i += j + 2;
                    *in_block_comment = false;
                }
                None => {
                    comment.push_str(&line[i..]);
                    i = b.len();
                }
            }
            continue;
        }
        match b[i] {
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                comment.push_str(&line[i + 2..]);
                break;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                *in_block_comment = true;
                i += 2;
            }
            b'"' => {
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
                code.push_str("\"\"");
            }
            b'\'' => {
                // Char literal iff it closes ('x' or '\x'); else lifetime.
                let is_char = i + 2 < b.len() && (b[i + 1] == b'\\' || b[i + 2] == b'\'');
                if is_char {
                    let mut j = i + 2;
                    while j < b.len() && b[j] != b'\'' {
                        j += 1;
                    }
                    i = j + 1;
                    code.push_str("''");
                } else {
                    code.push('\'');
                    i += 1;
                }
            }
            c => {
                code.push(c as char);
                i += 1;
            }
        }
    }
    SplitLine { code, comment }
}

/// Is `needle` present in `hay` with no identifier character on either side?
fn word(hay: &str, needle: &str) -> bool {
    let is_ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut from = 0;
    while let Some(j) = hay[from..].find(needle) {
        let at = from + j;
        let before_ok = at == 0 || !is_ident(hay.as_bytes()[at - 1]);
        let after = at + needle.len();
        let after_ok = after >= hay.len() || !is_ident(hay.as_bytes()[after]);
        if before_ok && after_ok {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// The identifier following `fn ` on this code line, if any.
fn fn_name(code: &str) -> Option<(usize, String)> {
    let mut from = 0;
    while let Some(j) = code[from..].find("fn ") {
        let at = from + j;
        let before_ok =
            at == 0 || !(code.as_bytes()[at - 1] == b'_' || code.as_bytes()[at - 1].is_ascii_alphanumeric());
        if before_ok {
            let rest = code[at + 3..].trim_start();
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some((at, name));
            }
        }
        from = at + 3;
    }
    None
}

/// Line range `[sig_line, end_line]` of the body of the fn declared at
/// `sig`, or None for body-less declarations (trait methods, externs).
fn fn_body_range(codes: &[String], sig: usize) -> Option<(usize, usize)> {
    let mut depth: i64 = 0;
    let mut found = false;
    let mut j = sig;
    while j < codes.len() {
        let c = &codes[j];
        if !found && c.contains(';') && !c.contains('{') {
            return None;
        }
        for ch in c.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    found = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if found && depth <= 0 {
            return Some((sig, j));
        }
        j += 1;
    }
    None
}

fn is_hot(name: &str) -> bool {
    HOT_SUFFIXES.iter().any(|s| name.ends_with(s)) || HOT_NAMES.contains(&name)
}

/// Lint one source file; `rel` is the path label used in diagnostics and
/// for the path-scoped rules (purity files, the tile-const home).
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let mut in_block = false;
    let mut codes: Vec<String> = Vec::new();
    let mut comments: Vec<String> = Vec::new();
    for line in src.lines() {
        let s = split_line(line, &mut in_block);
        codes.push(s.code);
        comments.push(s.comment);
    }
    let mut v = Vec::new();
    let flag = |v: &mut Vec<Violation>, line: usize, rule: &'static str, msg: String| {
        v.push(Violation { file: rel.to_string(), line: line + 1, rule, msg });
    };

    // --- unsafe-safety -----------------------------------------------------
    for (i, code) in codes.iter().enumerate() {
        if !word(code, "unsafe") {
            continue;
        }
        let mut ok = comments[i].contains("SAFETY:");
        let mut k = i;
        let mut budget = SAFETY_LOOKBACK;
        while !ok && k > 0 && budget > 0 {
            k -= 1;
            budget -= 1;
            let cs = codes[k].trim();
            if !(cs.is_empty() || cs.starts_with("#[")) {
                break; // a code line ends the comment block above `unsafe`
            }
            if comments[k].contains("SAFETY:") {
                ok = true;
            }
        }
        if !ok {
            flag(&mut v, i, "unsafe-safety", format!(
                "`unsafe` without a `// SAFETY:` comment: {}",
                codes[i].trim()
            ));
        }
    }

    // --- hot-alloc ---------------------------------------------------------
    let mut i = 0;
    while i < codes.len() {
        let Some((_, name)) = fn_name(&codes[i]) else {
            i += 1;
            continue;
        };
        let Some((start, end)) = fn_body_range(&codes, i) else {
            i += 1;
            continue;
        };
        if is_hot(&name) {
            for b in start..=end {
                for tok in ALLOC_TOKENS {
                    if codes[b].contains(tok) {
                        let lo = b.saturating_sub(ALLOC_OK_REACH);
                        let escaped = (lo..=b).any(|k| comments[k].contains("alloc-ok"));
                        if !escaped {
                            flag(&mut v, b, "hot-alloc", format!(
                                "`{tok}` in hot-path fn `{name}` (annotate warmup/fallback \
                                 sites with `// alloc-ok: <reason>`)"
                            ));
                        }
                    }
                }
            }
        }
        // Resume after the signature line: nested fns inside the body are
        // still discovered (the scan is per-line), outer fns are not
        // re-matched.
        i += 1;
    }

    // --- tile-const --------------------------------------------------------
    if !rel.ends_with(TILE_HOME) {
        for (i, code) in codes.iter().enumerate() {
            if let Some(name) = const_name(code) {
                if name.split('_').any(|seg| TILE_SEGMENTS.contains(&seg)) {
                    flag(&mut v, i, "tile-const", format!(
                        "tile/blocking const `{name}` outside {TILE_HOME} — kernels \
                         receive sizes from the layout planner, they do not define them"
                    ));
                }
            }
        }
    }

    // --- kernel-purity -----------------------------------------------------
    if PURITY_FILES.iter().any(|p| rel.ends_with(p)) {
        for (i, code) in codes.iter().enumerate() {
            for tok in PURITY_TOKENS {
                if code.contains(tok) {
                    flag(&mut v, i, "kernel-purity", format!(
                        "`{tok}` in a kernel/planner module — kernels compute, the \
                         exec layer schedules, benches time"
                    ));
                }
            }
        }
    }

    // --- telemetry-purity --------------------------------------------------
    if PURITY_FILES.iter().any(|p| rel.ends_with(p)) {
        for (i, code) in codes.iter().enumerate() {
            if code.contains(TELEMETRY_TOKEN) {
                flag(&mut v, i, "telemetry-purity", format!(
                    "`{TELEMETRY_TOKEN}` in a kernel/planner module — instrumentation \
                     lives at the boundary layers (step/coordinator/dist/pipeline); \
                     pure modules expose plain counters the telemetry report \
                     mirrors at read time (PR-9 convention)"
                ));
            }
        }
    }

    // --- exchange-combine --------------------------------------------------
    if codes.iter().any(|c| c.contains("impl Exchange for")) {
        let mut i = 0;
        while i < codes.len() {
            let hit = fn_name(&codes[i])
                .filter(|(_, n)| n == "all_reduce_mean" || n == "all_reduce_mean_into");
            let Some((_, name)) = hit else {
                i += 1;
                continue;
            };
            let Some((start, end)) = fn_body_range(&codes, i) else {
                i += 1;
                continue;
            };
            let body = codes[start..=end].join("\n");
            if !body.contains("combine") && !body.contains("self.all_reduce_mean") {
                flag(&mut v, i, "exchange-combine", format!(
                    "`{name}` does not route through the fixed-order combine helpers \
                     (or forward to self.all_reduce_mean) — the deterministic combine \
                     order is the PR-4 Exchange convention"
                ));
            }
            i = end + 1;
        }
    }

    // --- bare-sync ---------------------------------------------------------
    if !rel.ends_with(SYNC_HOME) {
        for (i, code) in codes.iter().enumerate() {
            if !code.contains("std::sync::") {
                continue;
            }
            for ty in BARE_SYNC_TYPES {
                if word(code, ty) {
                    flag(&mut v, i, "bare-sync", format!(
                        "bare `std::sync::{ty}` outside {SYNC_HOME} — lock/condvar \
                         primitives go through the `util::sync` shim so the loom \
                         lane can model-check them (PR-6 convention)"
                    ));
                    break;
                }
            }
        }
    }

    v
}

/// `const NAME:` / `pub const NAME:` declaration name on this code line.
fn const_name(code: &str) -> Option<String> {
    let at = code.find("const ")?;
    let before_ok = at == 0 || {
        let c = code.as_bytes()[at - 1];
        !(c == b'_' || c.is_ascii_alphanumeric())
    };
    if !before_ok {
        return None;
    }
    let rest = code[at + 6..].trim_start();
    let name: String =
        rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
    let after = rest[name.len()..].trim_start();
    // Screen-case consts only (`const fn`, generics like `const N: usize`
    // in signatures still match the colon form — acceptable: rule set is
    // name-based and generic params use single letters).
    if !name.is_empty() && after.starts_with(':') && name.chars().next().unwrap().is_ascii_uppercase()
    {
        Some(name)
    } else {
        None
    }
}

/// Allowlist: `(rule, file-suffix)` pairs parsed from lint_allow.txt.
pub fn parse_allowlist(text: &str) -> Vec<(String, String)> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let mut it = l.split_whitespace();
            Some((it.next()?.to_string(), it.next()?.to_string()))
        })
        .collect()
}

/// Recursively lint every `.rs` file under `root`, dropping violations the
/// allowlist covers.  Paths in diagnostics are relative to `root`.
pub fn lint_tree(root: &Path, allow: &[(String, String)]) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&f)?;
        out.extend(lint_source(&rel, &src).into_iter().filter(|v| {
            !allow.iter().any(|(rule, suffix)| *rule == v.rule && v.file.ends_with(suffix.as_str()))
        }));
    }
    Ok(out)
}

/// Like [`lint_tree`], but keeping only violations of the named rules.
/// Used for the test/bench/example/xtask trees, where only the
/// cross-cutting convention rules (today: bare-sync) apply — the hot-path
/// and unsafe discipline is `rust/src`-scoped.
pub fn lint_tree_rules(
    root: &Path,
    allow: &[(String, String)],
    rules: &[&str],
) -> io::Result<Vec<Violation>> {
    Ok(lint_tree(root, allow)?.into_iter().filter(|v| rules.contains(&v.rule)).collect())
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
        lint_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f() {\n    let p = unsafe { x.get_unchecked(0) };\n}\n";
        assert_eq!(rules_of("a.rs", bad), vec!["unsafe-safety"]);
        let same_line = "fn f() {\n    let p = unsafe { g() }; // SAFETY: g is total\n}\n";
        assert!(rules_of("a.rs", same_line).is_empty());
        let above = "fn f() {\n    // SAFETY: bounds checked above\n    let p = unsafe { g() };\n}\n";
        assert!(rules_of("a.rs", above).is_empty());
        // A code line between the comment and the unsafe breaks the link.
        let detached =
            "fn f() {\n    // SAFETY: stale\n    let n = 1;\n    let p = unsafe { g() };\n}\n";
        assert_eq!(rules_of("a.rs", detached), vec!["unsafe-safety"]);
        // `unsafe` in strings or comments is not code.
        let in_str = "fn f() { let s = \"unsafe\"; } // unsafe mentioned\n";
        assert!(rules_of("a.rs", in_str).is_empty());
    }

    #[test]
    fn hot_fn_allocations_are_flagged_and_alloc_ok_escapes() {
        let bad = "fn copy_into(d: &mut V) {\n    let t = vec![0f32; 8];\n}\n";
        assert_eq!(rules_of("a.rs", bad), vec!["hot-alloc"]);
        let escaped =
            "fn copy_into(d: &mut V) {\n    // alloc-ok: warmup only\n    let t = vec![0f32; 8];\n}\n";
        assert!(rules_of("a.rs", escaped).is_empty());
        // The escape reaches only ALLOC_OK_REACH lines down.
        let too_far = "fn grads_in_place(d: &mut V) {\n    // alloc-ok: warmup\n    let a = 1;\n    let b = 2;\n    let c = 3;\n    let t = x.clone();\n}\n";
        assert_eq!(rules_of("a.rs", too_far), vec!["hot-alloc"]);
        // Cold functions may allocate freely.
        let cold = "fn build() -> V {\n    vec![0f32; 8].to_vec()\n}\n";
        assert!(rules_of("a.rs", cold).is_empty());
        // micro_tile is hot by name.
        let micro = "fn micro_tile(a: &[f32]) {\n    let t = a.to_vec();\n}\n";
        assert_eq!(rules_of("a.rs", micro), vec!["hot-alloc"]);
    }

    #[test]
    fn tile_consts_belong_to_the_planner() {
        let bad = "pub const CONV_TILE: usize = 8;\n";
        assert_eq!(rules_of("runtime/kernel.rs", bad), vec!["tile-const"]);
        // Segment match, not substring: CONVERGENCE_STEPS contains "NC".
        let fine = "pub const CONVERGENCE_STEPS: usize = 150_000;\n";
        assert!(rules_of("repro/x.rs", fine).is_empty());
        // The planner itself is the sanctioned home.
        let home = "pub const CPU_MR: usize = 4;\n";
        assert!(rules_of("layout/plan.rs", home).is_empty());
        assert_eq!(rules_of("other.rs", home), vec!["tile-const"]);
        // Lane-selection constants (K-chain depth, vector-width assumptions)
        // are blocking policy too — same home, same rule (PR-8).
        let ku = "const GEMM_KU: usize = 2;\n";
        assert_eq!(rules_of("runtime/kernel.rs", ku), vec!["tile-const"]);
        let lanes = "pub const SIMD_LANES: usize = 8;\n";
        assert_eq!(rules_of("runtime/ref_conv.rs", lanes), vec!["tile-const"]);
        assert!(rules_of("layout/plan.rs", "pub const CPU_SIMD_KU: usize = 2;\n").is_empty());
        // "KURTOSIS_WINDOW" has no KU *segment* — substring matches stay out.
        assert!(rules_of("metrics/x.rs", "const KURTOSIS_WINDOW: usize = 9;\n").is_empty());
        // Gradient-exchange bucket sizing is blocking policy too (PR-10):
        // only the planner declares it; exchange lanes consume the plan.
        let bucket = "const EXCHANGE_BUCKET_BYTES: usize = 1 << 16;\n";
        assert_eq!(rules_of("dist/overlap.rs", bucket), vec!["tile-const"]);
        assert!(rules_of("layout/plan.rs", bucket).is_empty());
        // "BUCKETING_NOTE" has no BUCKET *segment* — substring stays out.
        assert!(rules_of("dist/x.rs", "const BUCKETING_LOG: usize = 1;\n").is_empty());
    }

    #[test]
    fn kernel_purity_is_path_scoped() {
        let bad = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(rules_of("runtime/kernel.rs", bad), vec!["kernel-purity"]);
        assert_eq!(rules_of("runtime/workspace.rs", bad), vec!["kernel-purity"]);
        // Outside the kernel/planner modules, timing is fine (benches).
        assert!(rules_of("bench/harness.rs", bad).is_empty());
    }

    #[test]
    fn telemetry_stays_out_of_pure_modules() {
        let bad = "fn f() { crate::telemetry::count(telemetry::Counter::FreeListHit, 1); }\n";
        assert_eq!(rules_of("runtime/kernel.rs", bad), vec!["telemetry-purity"]);
        assert_eq!(rules_of("runtime/workspace.rs", bad), vec!["telemetry-purity"]);
        assert_eq!(rules_of("layout/plan.rs", bad), vec!["telemetry-purity"]);
        let spanned = "fn f() { let _s = telemetry::span(telemetry::Phase::Apply); }\n";
        assert_eq!(rules_of("runtime/ref_conv.rs", spanned), vec!["telemetry-purity"]);
        // Boundary layers are exactly where instrumentation belongs.
        assert!(rules_of("runtime/step.rs", bad).is_empty());
        assert!(rules_of("pipeline/prefetcher.rs", bad).is_empty());
        assert!(rules_of("dist/async_ps.rs", spanned).is_empty());
        // Mentions in comments or string literals are not code.
        let comment = "fn f() {} // telemetry:: stays out of this module\n";
        assert!(rules_of("runtime/kernel.rs", comment).is_empty());
        let in_str = "fn f() { let t = \"paragan::telemetry::x\"; }\n";
        assert!(rules_of("runtime/kernel.rs", in_str).is_empty());
    }

    #[test]
    fn exchange_impls_must_combine_in_fixed_order() {
        let bad = "impl Exchange for Foo {\n    fn all_reduce_mean(&self, r: usize) -> R {\n        Ok(x)\n    }\n}\n";
        assert_eq!(rules_of("a.rs", bad), vec!["exchange-combine"]);
        let combine = "impl Exchange for Foo {\n    fn all_reduce_mean(&self, r: usize) -> R {\n        Self::combine(t)\n    }\n}\n";
        assert!(rules_of("a.rs", combine).is_empty());
        let forward = "impl Exchange for Foo {\n    fn all_reduce_mean_into(&self, r: usize) -> R {\n        self.all_reduce_mean(r)\n    }\n}\n";
        assert!(rules_of("a.rs", forward).is_empty());
        // Files without an Exchange impl are not checked.
        let elsewhere = "fn all_reduce_mean() {\n    Ok(x)\n}\n";
        assert!(rules_of("a.rs", elsewhere).is_empty());
    }

    #[test]
    fn bare_sync_primitives_must_come_from_the_shim() {
        let bad = "use std::sync::Mutex;\n";
        assert_eq!(rules_of("a.rs", bad), vec!["bare-sync"]);
        let braced = "use std::sync::{Arc, Condvar, Mutex};\n";
        assert_eq!(rules_of("a.rs", braced), vec!["bare-sync"]);
        let qualified = "static S: std::sync::Mutex<u32> = std::sync::Mutex::new(0);\n";
        assert_eq!(rules_of("a.rs", qualified), vec!["bare-sync"]);
        // Arc / Barrier / mpsc / atomics carry no loom-shim requirement.
        let fine = "use std::sync::{mpsc, Arc, Barrier};\nuse std::sync::atomic::AtomicUsize;\n";
        assert!(rules_of("a.rs", fine).is_empty());
        // The shim itself is the sanctioned home; anywhere else is not.
        let home = "pub use std::sync::{Condvar, Mutex, MutexGuard};\n";
        assert!(rules_of("util/sync.rs", home).is_empty());
        assert_eq!(rules_of("exec/mod.rs", home), vec!["bare-sync"]);
        // Shim-routed locks are exactly what the rule wants to see.
        let shim = "use crate::util::sync::{Condvar, Mutex};\n";
        assert!(rules_of("a.rs", shim).is_empty());
        // Mentions in comments are not code.
        let comment = "fn f() {} // std::sync::Mutex would be wrong here\n";
        assert!(rules_of("a.rs", comment).is_empty());
        // Word boundary: `MutexGuard`-like identifiers do not leak into a
        // `Mutex` match (each type is matched as its own word).
        let ident = "fn f(g: &std::sync::mpsc::Sender<MutexLike>) {}\n";
        assert!(rules_of("a.rs", ident).is_empty());
    }

    #[test]
    fn allowlist_parses_and_filters() {
        let allow = parse_allowlist("# comment\n\nhot-alloc runtime/legacy.rs\n");
        assert_eq!(allow, vec![("hot-alloc".to_string(), "runtime/legacy.rs".to_string())]);
        let v = Violation {
            file: "runtime/legacy.rs".into(),
            line: 3,
            rule: "hot-alloc",
            msg: String::new(),
        };
        assert!(allow.iter().any(|(r, s)| *r == v.rule && v.file.ends_with(s.as_str())));
    }

    /// THE gate: the real tree must be lint-clean.  Runs inside plain
    /// `cargo test` so tier-1 and the dedicated CI lint job enforce the
    /// same thing.
    #[test]
    fn paragan_source_tree_is_clean() {
        let ws = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
        let allow_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint_allow.txt");
        let allow = parse_allowlist(&fs::read_to_string(allow_path).unwrap_or_default());
        let mut viols = lint_tree(&ws.join("rust/src"), &allow).unwrap();
        // The cross-cutting bare-sync rule covers the whole workspace: a
        // test or bench taking a bare `std::sync::Mutex` would silently
        // fall out of the loom lane's coverage.
        for tree in ["rust/tests", "rust/benches", "rust/examples", "xtask/src"] {
            let root = ws.join(tree);
            if root.is_dir() {
                viols.extend(lint_tree_rules(&root, &allow, &["bare-sync"]).unwrap());
            }
        }
        assert!(
            viols.is_empty(),
            "paragan-lint violations:\n{}",
            viols.iter().map(|v| format!("  {v}\n")).collect::<String>()
        );
    }
}
