"""Guard the cross-language golden file (rust/tests/golden/ref_kernels.json).

The Rust RefCpuBackend parity test regenerates the same inputs from the
shared LCG and checks its matmul against these numbers; this test closes the
loop from the Python side by recomputing the goldens with the ref.py oracle
and diffing against the checked-in file.  If either side's kernel math (or
the LCG) drifts, one of the two tests fails.
"""

import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from tools.gen_golden import (  # noqa: E402
    BATCHNORM_CASES,
    CONV2D_CASES,
    CONVT2D_CASES,
    MATMUL_CASES,
    UPSAMPLE_CASES,
    Lcg,
    golden,
)

GOLDEN_PATH = os.path.normpath(
    os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", "ref_kernels.json"
    )
)

SECTIONS = {
    "matmul": MATMUL_CASES,
    "conv2d": CONV2D_CASES,
    "conv2d_transpose": CONVT2D_CASES,
    "batchnorm": BATCHNORM_CASES,
    "upsample": UPSAMPLE_CASES,
}


def test_lcg_reference_values():
    # Pinned in rust/tests/backend_parity.rs as well — keep all three in sync.
    lcg = Lcg(1)
    got = [lcg.next_f32() for _ in range(4)]
    np.testing.assert_allclose(
        got, [-0.15358174, 0.018814802, 0.29671872, -0.23427331], rtol=0, atol=1e-7
    )


def test_checked_in_golden_matches_ref_kernels():
    with open(GOLDEN_PATH) as f:
        stored = json.load(f)
    assert stored["format"] == "paragan-golden"
    fresh = golden()
    for section, case_list in SECTIONS.items():
        assert section in stored, f"golden file missing section '{section}'"
        assert [c["seed"] for c in stored[section]] == [c[0] for c in case_list], section
        for s_case, f_case in zip(stored[section], fresh[section]):
            assert {k: v for k, v in s_case.items() if k != "y"} == {
                k: v for k, v in f_case.items() if k != "y"
            }, f"{section} seed {s_case['seed']} config drifted"
            np.testing.assert_allclose(
                np.array(s_case["y"], dtype=np.float32),
                np.array(f_case["y"], dtype=np.float32),
                rtol=1e-5,
                atol=1e-6,
                err_msg=f"{section} seed {s_case['seed']}",
            )
