"""Guard the cross-language golden file (rust/tests/golden/ref_kernels.json).

The Rust RefCpuBackend parity test regenerates the same inputs from the
shared LCG and checks its matmul against these numbers; this test closes the
loop from the Python side by recomputing the goldens with the ref.py oracle
and diffing against the checked-in file.  If either side's kernel math (or
the LCG) drifts, one of the two tests fails.
"""

import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from tools.gen_golden import MATMUL_CASES, Lcg, golden  # noqa: E402

GOLDEN_PATH = os.path.normpath(
    os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", "ref_kernels.json"
    )
)


def test_lcg_reference_values():
    # Pinned in rust/tests/backend_parity.rs as well — keep all three in sync.
    lcg = Lcg(1)
    got = [lcg.next_f32() for _ in range(4)]
    np.testing.assert_allclose(
        got, [-0.15358174, 0.018814802, 0.29671872, -0.23427331], rtol=0, atol=1e-7
    )


def test_checked_in_golden_matches_ref_kernels():
    with open(GOLDEN_PATH) as f:
        stored = json.load(f)
    assert stored["format"] == "paragan-golden"
    fresh = golden()
    assert [c["seed"] for c in stored["matmul"]] == [c[0] for c in MATMUL_CASES]
    for s_case, f_case in zip(stored["matmul"], fresh["matmul"]):
        assert (s_case["m"], s_case["k"], s_case["n"]) == (
            f_case["m"],
            f_case["k"],
            f_case["n"],
        )
        np.testing.assert_allclose(
            np.array(s_case["y"], dtype=np.float32),
            np.array(f_case["y"], dtype=np.float32),
            rtol=1e-5,
            atol=1e-6,
            err_msg=f"seed {s_case['seed']}",
        )
