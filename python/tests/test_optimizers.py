"""Optimizer correctness: closed-form first steps, convergence, invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.optimizers import (HParams, OPTIMIZERS, adam_init, adam_update,
                                adabelief_init, adabelief_update,
                                clip_by_global_norm, global_grad_norm,
                                lars_init, lars_update, lookahead_init,
                                lookahead_update, radam_init, radam_update)

SETTINGS = dict(deadline=None, max_examples=10, derandomize=True)
HP = HParams(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8)


def _params():
    return {"w": jnp.array([[1.0, -2.0], [3.0, 0.5]]), "b": jnp.array([0.1, -0.1])}


def _grads():
    return {"w": jnp.array([[0.5, -0.5], [1.0, 0.0]]), "b": jnp.array([-1.0, 2.0])}


def test_adam_first_step_closed_form():
    """After one step from zero state, Adam moves by ~lr*sign(g) for g != 0."""
    p, g = _params(), _grads()
    newp, _ = adam_update(g, adam_init(p), p, 1.0, HP)
    expect = p["w"] - HP.lr * np.sign(np.asarray(g["w"]))
    mask = np.asarray(g["w"]) != 0
    np.testing.assert_allclose(np.asarray(newp["w"])[mask], np.asarray(expect)[mask], rtol=1e-3)
    # zero gradient -> no movement
    assert float(newp["w"][1, 1]) == pytest.approx(0.5)


def test_adam_descends_quadratic():
    p = {"x": jnp.array([5.0, -3.0])}
    s = adam_init(p)
    for t in range(1, 400):
        g = {"x": 2.0 * p["x"]}  # grad of ||x||^2
        p, s = adam_update(g, s, p, float(t), HParams(lr=5e-2))
    assert float(jnp.abs(p["x"]).max()) < 1e-2


@pytest.mark.parametrize("name", list(OPTIMIZERS.keys()))
def test_all_optimizers_descend(name):
    init, upd, _ = OPTIMIZERS[name]
    p = {"x": jnp.array([4.0, -4.0]), "y": jnp.array([[2.0]])}
    s = init(p)
    loss0 = float(sum(jnp.sum(v ** 2) for v in p.values()))
    for t in range(1, 300):
        g = {k: 2.0 * v for k, v in p.items()}
        p, s = upd(g, s, p, float(t), HParams(lr=3e-2, lars_trust=0.05))
    loss1 = float(sum(jnp.sum(v ** 2) for v in p.values()))
    assert loss1 < loss0 * 0.2, (name, loss0, loss1)


@pytest.mark.parametrize("name", list(OPTIMIZERS.keys()))
def test_state_shapes_match_params(name):
    init, upd, n_slots = OPTIMIZERS[name]
    p = _params()
    s = init(p)
    assert len(s) == n_slots
    for slot in s:
        assert set(slot.keys()) == set(p.keys())
        for k in p:
            assert slot[k].shape == p[k].shape
    newp, news = upd(_grads(), s, p, 1.0, HP)
    assert len(news) == n_slots
    for k in p:
        assert newp[k].shape == p[k].shape


def test_adabelief_differs_from_adam():
    p, g = _params(), _grads()
    pa, _ = adam_update(g, adam_init(p), p, 1.0, HP)
    pb, _ = adabelief_update(g, adabelief_init(p), p, 1.0, HP)
    # First-step AdaBelief denominator is (1-b1)^2 g^2-based -> bigger steps.
    assert not np.allclose(np.asarray(pa["w"]), np.asarray(pb["w"]))


def test_radam_warmup_is_sgd_like():
    """For small t, rho_t <= 4 and RAdam takes unadapted (SGD-with-momentum) steps."""
    p, g = _params(), _grads()
    newp, _ = radam_update(g, radam_init(p), p, 1.0, HP)
    # SGD branch: p - lr * mhat where mhat = g (bias-corrected first moment).
    expect = np.asarray(p["w"]) - HP.lr * np.asarray(g["w"])
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-5)


def test_lookahead_syncs_every_k():
    hp = HParams(lr=1e-2, la_k=5, la_alpha=0.5)
    p = {"x": jnp.array([1.0])}
    s = lookahead_init(p)
    slow0 = float(s[2]["x"][0])
    for t in range(1, 5):  # steps 1..4: no sync
        p, s = lookahead_update({"x": jnp.array([1.0])}, s, p, float(t), hp)
        assert float(s[2]["x"][0]) == pytest.approx(slow0)
    p, s = lookahead_update({"x": jnp.array([1.0])}, s, p, 5.0, hp)  # sync step
    assert float(s[2]["x"][0]) != pytest.approx(slow0)
    # After sync, fast weights equal slow weights.
    np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(s[2]["x"]), rtol=1e-6)


def test_lars_trust_ratio_scales_with_weight_norm():
    hp = HParams(lr=1.0, lars_trust=1e-3, lars_momentum=0.0)
    big = {"x": jnp.full((4,), 100.0)}
    small = {"x": jnp.full((4,), 0.01)}
    g = {"x": jnp.ones((4,))}
    pb, _ = lars_update(g, lars_init(big), big, 1.0, hp)
    ps, _ = lars_update(g, lars_init(small), small, 1.0, hp)
    step_big = float(jnp.abs(big["x"] - pb["x"]).max())
    step_small = float(jnp.abs(small["x"] - ps["x"]).max())
    assert step_big > step_small * 100  # layer-wise scaling


@given(max_norm=st.floats(0.1, 10.0), scale=st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_clip_by_global_norm(max_norm, scale):
    g = {"a": jnp.array([3.0 * scale]), "b": jnp.array([4.0 * scale])}
    clipped, norm = clip_by_global_norm(g, max_norm)
    assert float(norm) == pytest.approx(5.0 * scale, rel=1e-5)
    out_norm = float(global_grad_norm(clipped))
    assert out_norm <= max_norm * (1 + 1e-4)
    if 5.0 * scale <= max_norm:  # under the cap: untouched
        np.testing.assert_allclose(np.asarray(clipped["a"]), np.asarray(g["a"]), rtol=1e-6)
