"""AOT exporter: manifest schema, HLO text validity, flat-arg round-trip.

Runs a small export (dcgan32 only, tiny batch) into a tmpdir — fast enough
for CI — and checks the manifest is exactly what the rust
``runtime::artifact`` module expects.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import MODELS, init_params
from compile.optimizers import OPTIMIZERS
from compile.precision import FP32

from jax._src.lib import xla_client as xc


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    # Restrict to the cheap model and the asymmetric-policy pair.
    old = aot.EXPORT_SETS["dcgan32"]
    aot.EXPORT_SETS["dcgan32"] = {"opts": ["adam", "adabelief"], "precs": ["fp32"], "bf16_opts": []}
    try:
        aot.main(["--out", out, "--models", "dcgan32", "--batch", "4"])
    finally:
        aot.EXPORT_SETS["dcgan32"] = old
    return out


def test_manifest_schema(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    assert man["version"] == 1 and man["batch"] == 4
    m = man["models"]["dcgan32"]
    assert m["z_dim"] == 128 and m["img_shape"] == [3, 32, 32]
    assert m["loss"] == "bce" and m["n_classes"] == 0
    for art in ("d_step_adam_fp32", "g_step_adam_fp32", "d_step_adabelief_fp32",
                "g_step_adabelief_fp32", "generate_fp32", "fid_features"):
        assert art in m["artifacts"], art
        rec = m["artifacts"][art]
        assert os.path.exists(os.path.join(export_dir, rec["file"]))
        assert rec["inputs"] and rec["outputs"]


def test_manifest_roles_are_ordered_and_complete(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    m = man["models"]["dcgan32"]
    rec = m["artifacts"]["d_step_adam_fp32"]
    roles = [e["role"] for e in rec["inputs"]]
    nd = len(m["params_d"])
    assert roles[0] == "step"
    assert roles[1] == "lr"
    assert all(r.startswith("param:") for r in roles[2 : 2 + nd])
    assert all(r.startswith("slot0:") for r in roles[2 + nd : 2 + 2 * nd])
    assert all(r.startswith("slot1:") for r in roles[2 + 2 * nd : 2 + 3 * nd])
    assert roles[2 + 3 * nd :] == ["in:real", "in:fake"]
    out_roles = [e["role"] for e in rec["outputs"]]
    assert out_roles[-3:] == ["out:loss", "out:real_logits", "out:fake_logits"]
    # Param roles in outputs mirror inputs (state round-trips through rust).
    assert out_roles[: 3 * nd] == roles[2 : 2 + 3 * nd]


def test_hlo_text_parses_back(export_dir):
    """The emitted text must survive an HLO-text parse (what rust does)."""
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    rec = man["models"]["dcgan32"]["artifacts"]["generate_fp32"]
    text = open(os.path.join(export_dir, rec["file"])).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Entry computation arity matches the manifest.
    assert f"parameter({len(rec['inputs']) - 1})" in text or len(rec["inputs"]) == 1


def test_exported_step_numerics_match_eager(export_dir):
    """Execute the exported d_step HLO through XLA's python client and compare
    to the eager step — the same check the rust integration test performs."""
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    mrec = man["models"]["dcgan32"]
    rec = mrec["artifacts"]["d_step_adam_fp32"]
    text = open(os.path.join(export_dir, rec["file"])).read()

    # Rebuild the eager step.
    from compile.model import make_d_step
    from compile.optimizers import HParams
    model = MODELS["dcgan32"]()
    hp = HParams(lr=2e-4, b1=0.5, eps=FP32.adam_eps())
    d_step = make_d_step(model, "adam", FP32, hp)

    key = jax.random.PRNGKey(0)
    dp = init_params(model.d_spec, key)
    opt = OPTIMIZERS["adam"][0](dp)
    real = jnp.tanh(jax.random.normal(jax.random.PRNGKey(1), (4, 3, 32, 32)))
    fake = jnp.tanh(jax.random.normal(jax.random.PRNGKey(2), (4, 3, 32, 32)))

    want_p, want_s, want_loss, want_rl, want_fl = d_step(1.0, 2e-4, dp, opt, real, fake)

    # Flat-arg order per manifest.
    flat_inputs = [jnp.array(1.0, jnp.float32), jnp.array(2e-4, jnp.float32)]
    flat_inputs += [dp[e["name"]] for e in mrec["params_d"]]
    for k in range(2):
        flat_inputs += [opt[k][e["name"]] for e in mrec["params_d"]]
    flat_inputs += [real, fake]

    # Compile the HLO text with the in-process XLA client (if this jax build
    # exposes an HLO-text parser; the rust integration test covers the path
    # regardless).
    parser = getattr(xc._xla, "hlo_text_to_xla_computation", None)
    if parser is None:
        pytest.skip("python xla client lacks an HLO-text parser; rust covers this path")
    client = xc._xla.get_tfrt_cpu_client(asynchronous=False)
    exe = client.compile(parser(text))
    outs = exe.execute([np.asarray(x) for x in flat_inputs])
    nd = len(mrec["params_d"])
    got_loss = np.asarray(outs[3 * nd])
    np.testing.assert_allclose(got_loss, float(want_loss), rtol=1e-4)
