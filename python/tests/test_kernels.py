"""L1 kernel correctness: Pallas layout_matmul / conv2d vs pure-jnp oracles.

Hypothesis sweeps shapes (including awkward non-tile-aligned ones — the whole
point of the layout transformation) and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.layout_matmul import (
    LANE, SUBLANE, MatmulPlan, VMEM_BUDGET_BYTES, layout_matmul,
    layout_matmul_bf16, make_layout_matmul, opportunistic_batch_matmul, pad2d,
    plan_matmul, round_up,
)
from compile.kernels.conv2d import conv2d, conv2d_transpose, dense
from compile.kernels.ref import ref_conv2d, ref_conv2d_transpose, ref_matmul

SETTINGS = dict(deadline=None, max_examples=12, derandomize=True)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# plan_matmul / padding unit tests
# ---------------------------------------------------------------------------

def test_round_up():
    assert round_up(1, 8) == 8
    assert round_up(8, 8) == 8
    assert round_up(129, 128) == 256
    assert round_up(0, 128) == 0


@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300))
@settings(**SETTINGS)
def test_plan_invariants(m, k, n):
    p = plan_matmul(m, k, n)
    # Padded dims are tile multiples and cover the logical dims.
    assert p.mp % SUBLANE == 0 and p.mp >= m and p.mp - m < SUBLANE
    assert p.kp % LANE == 0 and p.kp >= k and p.kp - k < LANE
    assert p.np_ % LANE == 0 and p.np_ >= n and p.np_ - n < LANE
    # Blocks tile the padded dims exactly.
    assert p.mp % p.bm == 0 and p.kp % p.bk == 0 and p.np_ % p.bn == 0
    # VMEM budget respected (bk==LANE is the floor).
    assert p.vmem_bytes() <= VMEM_BUDGET_BYTES or p.bk == LANE
    assert 0.0 < p.mxu_occupancy() <= 1.0


def test_plan_aligned_shapes_have_full_occupancy():
    p = plan_matmul(256, 512, 128)
    assert p.mxu_occupancy() == 1.0
    assert p.padding_waste() == 0.0


def test_plan_tiny_shape_waste_is_large():
    # The paper's [100,100] example: 39% of a 128x128 MXU wasted.
    p = plan_matmul(100, 100, 100)
    assert p.padding_waste() > 0.2


def test_pad2d_shapes():
    x = jnp.ones((5, 70))
    xp, (r, c) = pad2d(x)
    assert xp.shape == (8, 128) and (r, c) == (5, 70)
    assert float(xp[5:].sum()) == 0.0 and float(xp[:, 70:].sum()) == 0.0
    y = jnp.ones((8, 128))
    yp, _ = pad2d(y)
    assert yp is y  # no-op when already aligned


# ---------------------------------------------------------------------------
# layout_matmul vs reference
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 130), k=st.integers(1, 140), n=st.integers(1, 150),
    seed=st.integers(0, 5),
)
@settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        np.asarray(layout_matmul(x, w)), np.asarray(ref_matmul(x, w)),
        rtol=1e-5, atol=1e-4,
    )


@pytest.mark.parametrize("shape", [(8, 128, 128), (1, 1, 1), (7, 129, 255), (64, 64, 64)])
def test_matmul_edge_shapes(shape):
    m, k, n = shape
    x, w = _rand(0, (m, k)), _rand(1, (k, n))
    np.testing.assert_allclose(
        np.asarray(layout_matmul(x, w)), np.asarray(ref_matmul(x, w)),
        rtol=1e-5, atol=1e-4,
    )


def test_matmul_grad_matches_ref():
    x, w = _rand(0, (33, 70)), _rand(1, (70, 17))
    gx, gw = jax.grad(lambda x, w: (layout_matmul(x, w) ** 2).sum(), argnums=(0, 1))(x, w)
    rx, rw = jax.grad(lambda x, w: (ref_matmul(x, w) ** 2).sum(), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-4, atol=1e-3)


def test_matmul_bf16_close_to_ref():
    x, w = _rand(0, (40, 96)), _rand(1, (96, 50))
    out = np.asarray(layout_matmul_bf16(x, w))
    ref = np.asarray(ref_matmul(x, w))
    # bf16 has ~8 bits of mantissa; tolerances scale with |ref|.
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-1)


def test_opportunistic_batching_exact():
    w = _rand(9, (60, 33))
    xs = [_rand(i, (r, 60)) for i, r in enumerate([5, 17, 8])]
    outs = opportunistic_batch_matmul(xs, w)
    for x, o in zip(xs, outs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref_matmul(x, w)),
                                   rtol=1e-5, atol=1e-4)


def test_make_layout_matmul_dtype_instances_differ():
    x, w = _rand(0, (16, 128)), _rand(1, (128, 128))
    f32 = np.asarray(make_layout_matmul("float32")(x, w))
    bf16 = np.asarray(make_layout_matmul("bfloat16")(x, w))
    assert not np.allclose(f32, bf16)  # precision policy actually changes math


# ---------------------------------------------------------------------------
# conv2d / conv2d_transpose vs reference
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 3), cin=st.integers(1, 5), cout=st.integers(1, 6),
    hw=st.sampled_from([5, 8, 12]), k=st.sampled_from([1, 3, 4]),
    stride=st.sampled_from([1, 2]), seed=st.integers(0, 3),
)
@settings(**SETTINGS)
def test_conv2d_matches_ref(b, cin, cout, hw, k, stride, seed):
    pad = k // 2
    x = _rand(seed, (b, cin, hw, hw))
    w = _rand(seed + 1, (cout, cin, k, k))
    bias = _rand(seed + 2, (cout,))
    out = conv2d(x, w, bias, stride, pad)
    ref = ref_conv2d(x, w, bias, stride, pad)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


@given(
    b=st.integers(1, 2), cin=st.sampled_from([2, 4]), cout=st.sampled_from([3, 8]),
    hw=st.sampled_from([4, 8]), seed=st.integers(0, 3),
)
@settings(**SETTINGS)
def test_conv2d_transpose_matches_ref(b, cin, cout, hw, seed):
    x = _rand(seed, (b, cin, hw, hw))
    w = _rand(seed + 1, (cin, cout, 4, 4))
    bias = _rand(seed + 2, (cout,))
    out = conv2d_transpose(x, w, bias, stride=2, padding=1)
    ref = ref_conv2d_transpose(x, w, bias, stride=2, padding=1)
    assert out.shape == (b, cout, hw * 2, hw * 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


def test_conv2d_transpose_stride1():
    x = _rand(0, (1, 3, 6, 6))
    w = _rand(1, (3, 5, 3, 3))
    out = conv2d_transpose(x, w, None, stride=1, padding=1)
    ref = ref_conv2d_transpose(x, w, None, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


def test_conv_grad_flows():
    x = _rand(0, (2, 3, 8, 8))
    w = _rand(1, (4, 3, 3, 3))
    g = jax.grad(lambda w: conv2d(x, w, None, 1, 1).sum())(w)
    gr = jax.grad(lambda w: ref_conv2d(x, w, None, 1, 1).sum())(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-3)


def test_dense_matches_matmul():
    x, w, b = _rand(0, (9, 31)), _rand(1, (31, 7)), _rand(2, (7,))
    out = dense(x, w, b)
    ref = ref_matmul(x, w) + b[None, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)
