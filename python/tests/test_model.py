"""L2 model zoo: shapes, losses, spectral norm, precision policy, train steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (LOSSES, MODELS, bce_d_loss, bce_g_loss,
                           hinge_d_loss, hinge_g_loss, init_params, lrelu,
                           make_d_step, make_fid_features, make_g_step,
                           make_generate, spectral_norm, FID_FEAT_DIM)
from compile.optimizers import OPTIMIZERS, HParams
from compile.precision import BF16, FP32

B = 4


def _setup(name):
    m = MODELS[name]()
    k = jax.random.PRNGKey(0)
    gp = init_params(m.g_spec, k)
    dp = init_params(m.d_spec, jax.random.PRNGKey(1))
    z = jax.random.normal(jax.random.PRNGKey(2), (B, m.z_dim))
    y = jax.nn.one_hot(jnp.arange(B) % m.n_classes, m.n_classes) if m.conditional else None
    return m, gp, dp, z, y


@pytest.mark.parametrize("name", list(MODELS.keys()))
def test_generator_output_shape_and_range(name):
    m, gp, dp, z, y = _setup(name)
    img = m.g_apply(gp, z, y, FP32)
    assert img.shape == (B,) + m.img_shape
    assert float(jnp.abs(img).max()) <= 1.0  # tanh output


@pytest.mark.parametrize("name", list(MODELS.keys()))
def test_discriminator_output_shape(name):
    m, gp, dp, z, y = _setup(name)
    x = jnp.tanh(jax.random.normal(jax.random.PRNGKey(3), (B,) + m.img_shape))
    logits = m.d_apply(dp, x, y, FP32)
    assert logits.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["dcgan32", "sngan32"])
def test_d_step_decreases_d_loss(name):
    """A few D steps on fixed batches should reduce the discriminator loss."""
    m, gp, dp, z, y = _setup(name)
    real = jnp.tanh(jax.random.normal(jax.random.PRNGKey(4), (B,) + m.img_shape))
    fake = m.g_apply(gp, z, y, FP32)
    step_fn = make_d_step(m, "adam", FP32, HParams(lr=1e-3, b1=0.5))
    opt = OPTIMIZERS["adam"][0](dp)
    losses = []
    for t in range(1, 9):
        dp, opt, loss, rl, fl = step_fn(float(t), 1e-3, dp, opt, real, fake, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_g_step_updates_only_g_params():
    m, gp, dp, z, y = _setup("dcgan32")
    step_fn = make_g_step(m, "adabelief", FP32, HParams(lr=1e-3))
    opt = OPTIMIZERS["adabelief"][0](gp)
    new_gp, new_opt, loss, fake = step_fn(1.0, 1e-3, gp, opt, dp, z, y)
    assert fake.shape == (B,) + m.img_shape
    changed = any(
        not np.allclose(np.asarray(gp[k]), np.asarray(new_gp[k])) for k in gp
    )
    assert changed and np.isfinite(float(loss))


def test_g_step_with_stale_d_params_is_well_defined():
    """The async scheme feeds g_step a STALE D snapshot; loss must stay finite
    and the G update must still move against that snapshot."""
    m, gp, dp, z, y = _setup("dcgan32")
    stale_dp = {k: v * 0.5 for k, v in dp.items()}  # a clearly different snapshot
    step_fn = make_g_step(m, "adam", FP32, HParams(lr=1e-3))
    opt = OPTIMIZERS["adam"][0](gp)
    _, _, loss_fresh, _ = step_fn(1.0, 1e-3, gp, opt, dp, z, y)
    _, _, loss_stale, _ = step_fn(1.0, 1e-3, gp, opt, stale_dp, z, y)
    assert np.isfinite(float(loss_fresh)) and np.isfinite(float(loss_stale))
    assert float(loss_fresh) != float(loss_stale)


def test_spectral_norm_bounds_sigma():
    k = jax.random.PRNGKey(0)
    w = 5.0 * jax.random.normal(k, (16, 8, 3, 3))
    wn = spectral_norm(w, iters=8)
    sigma = float(jnp.linalg.norm(wn.reshape(16, -1), ord=2))
    assert sigma == pytest.approx(1.0, rel=0.15)  # power-iteration estimate


def test_spectral_norm_identity_for_unit_sigma():
    w = jnp.eye(4).reshape(4, 4, 1, 1)
    wn = spectral_norm(w, iters=16)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(w), rtol=0.1)


def test_losses_signs():
    real = jnp.array([3.0, 2.0])
    fake = jnp.array([-3.0, -2.0])
    # Confident-correct D: low loss in both formulations.
    assert float(bce_d_loss(real, fake)) < 0.2
    assert float(hinge_d_loss(real, fake)) == 0.0
    # Confident-wrong D: high loss.
    assert float(bce_d_loss(fake, real)) > 2.0
    assert float(hinge_d_loss(fake, real)) > 2.0
    # G wants fake logits high.
    assert float(bce_g_loss(real)) < float(bce_g_loss(fake))
    assert float(hinge_g_loss(real)) < float(hinge_g_loss(fake))


def test_bf16_policy_changes_activations_not_output_dtype():
    m, gp, dp, z, y = _setup("dcgan32")
    img32 = m.g_apply(gp, z, y, FP32)
    img16 = m.g_apply(gp, z, y, BF16)
    assert img16.dtype == jnp.float32  # outputs stay f32 at the interface
    # The middle layers ran bf16: results differ but are close.
    assert not np.allclose(np.asarray(img32), np.asarray(img16))
    np.testing.assert_allclose(np.asarray(img32), np.asarray(img16), atol=0.15)


def test_precision_first_last_layer_fp32():
    assert BF16.act_dtype(0, 4) == "float32"
    assert BF16.act_dtype(3, 4) == "float32"
    assert BF16.act_dtype(1, 4) == "bfloat16"
    assert BF16.act_dtype(2, 4) == "bfloat16"
    assert FP32.act_dtype(1, 4) == "float32"
    assert BF16.adam_eps() > FP32.adam_eps()


def test_generate_matches_g_apply():
    m, gp, dp, z, y = _setup("sngan32")
    gen = make_generate(m, FP32)
    np.testing.assert_allclose(
        np.asarray(gen(gp, z, y)), np.asarray(m.g_apply(gp, z, y, FP32)), rtol=1e-6
    )


def test_fid_features_shape_and_determinism():
    m, gp, dp, z, y = _setup("dcgan32")
    feats_fn = make_fid_features(m.img_shape)
    x = jnp.tanh(jax.random.normal(jax.random.PRNGKey(7), (B,) + m.img_shape))
    f1, f2 = feats_fn(x), feats_fn(x)
    assert f1.shape == (B, FID_FEAT_DIM)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2))
    # Distinct images -> distinct features.
    f3 = feats_fn(-x)
    assert not np.allclose(np.asarray(f1), np.asarray(f3))


def test_biggan_projection_uses_labels():
    m, gp, dp, z, y = _setup("biggan32")
    x = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5), (B,) + m.img_shape))
    y2 = jnp.roll(y, 1, axis=0)
    l1 = m.d_apply(dp, x, y, FP32)
    l2 = m.d_apply(dp, x, y2, FP32)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_param_counts_reasonable():
    for name, ctor in MODELS.items():
        m = ctor()
        n_g = sum(int(np.prod(s)) for _, s, _ in m.g_spec)
        n_d = sum(int(np.prod(s)) for _, s, _ in m.d_spec)
        assert 1e4 < n_g < 5e6, (name, n_g)
        assert 1e4 < n_d < 5e6, (name, n_d)
