"""Pure-JAX optimizers for ParaGAN's asymmetric optimization policy (paper §5.2).

The paper: "ParaGAN firstly implements some of the latest work on optimizers
including Adabelief, rectified Adam (RAdam), Lookahead, and LARS" and then
pairs *different* optimizers for generator vs discriminator (AdaBelief for G,
Adam for D is the paper's winning pair, Fig. 6).

Implemented from the original papers (optax is not available offline):

  * Adam       — Kingma & Ba 2015
  * AdaBelief  — Zhuang et al. 2020 (variance of gradient *prediction error*)
  * RAdam      — Liu et al. 2020 (variance rectification warmup)
  * Lookahead  — Zhang et al. 2019 (k-step fast weights, slow-weight sync),
                 wrapped around an inner Adam
  * LARS       — You, Gitman & Ginsburg 2017 (layer-wise trust ratio), the
                 large-batch optimizer of the paper's own third author

Each optimizer is ``(init, update, n_slots)`` over pytrees:

  state = init(params)                         # tuple of n_slots pytrees
  new_params, new_state = update(grads, state, params, step, hparams)

``step`` is a float scalar (1-based) traced into the HLO so the whole update
is part of the AOT-compiled training step; the rust coordinator just feeds an
incrementing scalar.  All state slots are f32 pytrees shaped like params so
the rust ``ParamStore`` can host them generically.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


@dataclass(frozen=True)
class HParams:
    """Optimizer hyper-parameters; the scaling manager rewrites ``lr``."""

    lr: float = 2e-4
    b1: float = 0.5  # GAN-customary beta1 (DCGAN/BigGAN use 0.0-0.5)
    b2: float = 0.999
    eps: float = 1e-8  # paper §4.3: bump for bf16 runs
    weight_decay: float = 0.0
    # Lookahead
    la_k: int = 5
    la_alpha: float = 0.5
    # LARS
    lars_trust: float = 1e-3
    lars_momentum: float = 0.9


def _zeros_like(params):
    return tmap(jnp.zeros_like, params)


def _bias_corr(beta, step):
    return 1.0 - jnp.power(beta, step)


# --------------------------------------------------------------------------
# Adam
# --------------------------------------------------------------------------

def adam_init(params):
    return (_zeros_like(params), _zeros_like(params))


def adam_update(grads, state, params, step, hp: HParams, lr=None):
    lr = hp.lr if lr is None else lr
    m, v = state
    m = tmap(lambda m_, g: hp.b1 * m_ + (1 - hp.b1) * g, m, grads)
    v = tmap(lambda v_, g: hp.b2 * v_ + (1 - hp.b2) * g * g, v, grads)
    mc1, vc1 = _bias_corr(hp.b1, step), _bias_corr(hp.b2, step)
    new_params = tmap(
        lambda p, m_, v_: p - lr * (m_ / mc1) / (jnp.sqrt(v_ / vc1) + hp.eps),
        params, m, v,
    )
    return new_params, (m, v)


# --------------------------------------------------------------------------
# AdaBelief — second moment tracks (g - m)^2, the "belief" in the gradient.
# --------------------------------------------------------------------------

def adabelief_init(params):
    return (_zeros_like(params), _zeros_like(params))


def adabelief_update(grads, state, params, step, hp: HParams, lr=None):
    lr = hp.lr if lr is None else lr
    m, s = state
    m = tmap(lambda m_, g: hp.b1 * m_ + (1 - hp.b1) * g, m, grads)
    s = tmap(
        lambda s_, g, m_: hp.b2 * s_ + (1 - hp.b2) * (g - m_) * (g - m_) + hp.eps,
        s, grads, m,
    )
    mc1, sc1 = _bias_corr(hp.b1, step), _bias_corr(hp.b2, step)
    new_params = tmap(
        lambda p, m_, s_: p - lr * (m_ / mc1) / (jnp.sqrt(s_ / sc1) + hp.eps),
        params, m, s,
    )
    return new_params, (m, s)


# --------------------------------------------------------------------------
# RAdam — rectify the adaptive LR variance during warmup.
# --------------------------------------------------------------------------

def radam_init(params):
    return (_zeros_like(params), _zeros_like(params))


def radam_update(grads, state, params, step, hp: HParams, lr=None):
    lr = hp.lr if lr is None else lr
    m, v = state
    m = tmap(lambda m_, g: hp.b1 * m_ + (1 - hp.b1) * g, m, grads)
    v = tmap(lambda v_, g: hp.b2 * v_ + (1 - hp.b2) * g * g, v, grads)
    mc1 = _bias_corr(hp.b1, step)
    rho_inf = 2.0 / (1.0 - hp.b2) - 1.0
    b2t = jnp.power(hp.b2, step)
    rho_t = rho_inf - 2.0 * step * b2t / (1.0 - b2t)
    # Rectification term (defined for rho_t > 4).
    r_num = (rho_t - 4.0) * (rho_t - 2.0) * rho_inf
    r_den = (rho_inf - 4.0) * (rho_inf - 2.0) * rho_t
    rect = jnp.sqrt(jnp.maximum(r_num, 0.0) / r_den)
    use_adaptive = rho_t > 4.0

    def upd(p, m_, v_):
        mhat = m_ / mc1
        vhat = jnp.sqrt(v_ / _bias_corr(hp.b2, step)) + hp.eps
        adaptive = p - lr * rect * mhat / vhat
        sgd = p - lr * mhat
        return jnp.where(use_adaptive, adaptive, sgd)

    return tmap(upd, params, m, v), (m, v)


# --------------------------------------------------------------------------
# Lookahead(Adam) — fast weights take k Adam steps, slow weights interpolate.
# Branch-free: the sync happens via jnp.where(step % k == 0).
# --------------------------------------------------------------------------

def lookahead_init(params):
    m, v = adam_init(params)
    slow = tmap(jnp.array, params)
    return (m, v, slow)


def lookahead_update(grads, state, params, step, hp: HParams, lr=None):
    m, v, slow = state
    fast, (m, v) = adam_update(grads, (m, v), params, step, hp, lr)
    sync = jnp.equal(jnp.mod(step, float(hp.la_k)), 0.0)

    def blend(s, f):
        s_new = s + hp.la_alpha * (f - s)
        return jnp.where(sync, s_new, s), jnp.where(sync, s_new, f)

    pairs = tmap(blend, slow, fast)
    new_slow = tmap(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_fast = tmap(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_fast, (m, v, new_slow)


# --------------------------------------------------------------------------
# LARS — layer-wise adaptive rate scaling with momentum.
# --------------------------------------------------------------------------

def lars_init(params):
    return (_zeros_like(params),)


def lars_update(grads, state, params, step, hp: HParams, lr=None):
    lr = hp.lr if lr is None else lr
    (mom,) = state

    def upd(p, g, mo):
        wn = jnp.sqrt(jnp.sum(p * p))
        gn = jnp.sqrt(jnp.sum(g * g))
        trust = jnp.where(
            (wn > 0.0) & (gn > 0.0),
            hp.lars_trust * wn / (gn + hp.weight_decay * wn + 1e-12),
            1.0,
        )
        local_lr = lr * trust
        mo_new = hp.lars_momentum * mo + local_lr * (g + hp.weight_decay * p)
        return p - mo_new, mo_new

    pairs = tmap(upd, params, grads, mom)
    new_p = tmap(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_m = tmap(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, (new_m,)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

OPTIMIZERS: Dict[str, Tuple[Callable, Callable, int]] = {
    "adam": (adam_init, adam_update, 2),
    "adabelief": (adabelief_init, adabelief_update, 2),
    "radam": (radam_init, radam_update, 2),
    "lookahead": (lookahead_init, lookahead_update, 3),
    "lars": (lars_init, lars_update, 1),
}


def global_grad_norm(grads):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))


def clip_by_global_norm(grads, max_norm: float):
    """Gradient-norm clipping — part of the paper's per-network policy knobs."""
    norm = global_grad_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return tmap(lambda g: g * scale, grads), norm
