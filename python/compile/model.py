"""L2: GAN model zoo — generator/discriminator fwd+bwd+optimizer as JAX.

This is ParaGAN's "network backbones" layer (paper §3.1.2).  Three backbones,
scaled to what one CPU core can train end-to-end (the full-size BigGAN-128
appears in the rust cluster simulator's analytical workload models instead —
see DESIGN.md §1):

  * ``dcgan32``  — unconditional DCGAN (Radford et al. 2015), BCE loss.
  * ``sngan32``  — DCGAN topology with spectrally-normalized discriminator
                   (Miyato et al. 2018), hinge loss.
  * ``biggan32`` — class-conditional residual GAN in the BigGAN style (Brock
                   et al. 2019): FiLM-conditioned G res-blocks, projection
                   discriminator, spectral norm, hinge loss.

Every FLOP flows through the L1 Pallas kernels (`conv2d`, `conv2d_transpose`,
`dense`), so the paper's hardware-aware layout transformation applies to the
whole fwd+bwd.  The training *step* functions (``make_d_step`` /
``make_g_step``) close over an optimizer from `optimizers.py` and a
`precision.Precision` policy; `aot.py` lowers each combination to HLO text.

The step signatures are shaped for the paper's ASYNC UPDATE SCHEME (§5.1):
``d_step`` takes fake images as a *tensor input* (rust's ``img_buff``) rather
than regenerating them, and ``g_step`` takes a *snapshot* of discriminator
params (rust's weight snapshot) — so the rust coordinator can run G and D
steps in parallel on stale buffers, exactly as Fig. 5 (right) describes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.conv2d import conv2d, conv2d_transpose, dense
from .optimizers import OPTIMIZERS, HParams, clip_by_global_norm
from .precision import Precision

# ---------------------------------------------------------------------------
# Param specs and init
# ---------------------------------------------------------------------------

# (name, shape, init) with init in {"normal:<std>", "zeros", "ones"}.
ParamSpec = List[Tuple[str, Tuple[int, ...], str]]


def init_params(spec: ParamSpec, key) -> Dict[str, jnp.ndarray]:
    params = {}
    for name, shape, init in spec:
        if init.startswith("normal:"):
            std = float(init.split(":")[1])
            key, sub = jax.random.split(key)
            params[name] = std * jax.random.normal(sub, shape, dtype=jnp.float32)
        elif init == "zeros":
            params[name] = jnp.zeros(shape, dtype=jnp.float32)
        elif init == "ones":
            params[name] = jnp.ones(shape, dtype=jnp.float32)
        else:
            raise ValueError(init)
    return params


def lrelu(x, slope: float = 0.2):
    return jnp.where(x >= 0, x, slope * x)


def spectral_norm(w: jnp.ndarray, iters: int = 3) -> jnp.ndarray:
    """Stateless spectral normalization (SNGAN): power iteration from a fixed
    start vector, recomputed per step.  Keeping it stateless avoids threading
    auxiliary ``u`` buffers through the AOT interface; with 3 iterations the
    estimate is within a few percent of the true sigma for conv-sized
    matrices, which is what SNGAN needs (a Lipschitz *bound*, not an exact
    norm)."""
    wm = w.reshape(w.shape[0], -1)
    u = jnp.ones((wm.shape[0],), dtype=jnp.float32) / math.sqrt(wm.shape[0])
    for _ in range(iters):
        v = wm.T @ u
        v = v / (jnp.linalg.norm(v) + 1e-12)
        u = wm @ v
        u = u / (jnp.linalg.norm(u) + 1e-12)
    sigma = u @ (wm @ v)
    return w / (sigma + 1e-12)


# ---------------------------------------------------------------------------
# Losses (paper's backbones use BCE for DCGAN, hinge for SNGAN/BigGAN)
# ---------------------------------------------------------------------------

def bce_d_loss(real_logits, fake_logits):
    return jnp.mean(jax.nn.softplus(-real_logits)) + jnp.mean(jax.nn.softplus(fake_logits))


def bce_g_loss(fake_logits):
    return jnp.mean(jax.nn.softplus(-fake_logits))


def hinge_d_loss(real_logits, fake_logits):
    return jnp.mean(jax.nn.relu(1.0 - real_logits)) + jnp.mean(jax.nn.relu(1.0 + fake_logits))


def hinge_g_loss(fake_logits):
    return -jnp.mean(fake_logits)


LOSSES = {"bce": (bce_d_loss, bce_g_loss), "hinge": (hinge_d_loss, hinge_g_loss)}


# ---------------------------------------------------------------------------
# Model definition container
# ---------------------------------------------------------------------------

@dataclass
class ModelDef:
    """A GAN backbone: param specs + pure apply functions."""

    name: str
    z_dim: int
    img_shape: Tuple[int, int, int]  # (C, H, W)
    n_classes: int  # 0 = unconditional
    loss: str
    g_spec: ParamSpec
    d_spec: ParamSpec
    # g_apply(params, z, y_onehot|None, precision) -> images in [-1, 1]
    g_apply: Callable = None
    # d_apply(params, x, y_onehot|None, precision) -> logits (B,)
    d_apply: Callable = None

    @property
    def conditional(self) -> bool:
        return self.n_classes > 0


# ---------------------------------------------------------------------------
# DCGAN-32 (also the chassis for SNGAN-32)
# ---------------------------------------------------------------------------

def _dcgan_specs(gf: int = 32, df: int = 32, z_dim: int = 128) -> Tuple[ParamSpec, ParamSpec]:
    g_spec = [
        ("g.dense.w", (z_dim, 4 * 4 * gf * 4), "normal:0.02"),
        ("g.dense.b", (4 * 4 * gf * 4,), "zeros"),
        ("g.convt1.w", (gf * 4, gf * 2, 4, 4), "normal:0.02"),
        ("g.convt1.b", (gf * 2,), "zeros"),
        ("g.convt2.w", (gf * 2, gf, 4, 4), "normal:0.02"),
        ("g.convt2.b", (gf,), "zeros"),
        ("g.convt3.w", (gf, 3, 4, 4), "normal:0.02"),
        ("g.convt3.b", (3,), "zeros"),
    ]
    d_spec = [
        ("d.conv1.w", (df, 3, 4, 4), "normal:0.02"),
        ("d.conv1.b", (df,), "zeros"),
        ("d.conv2.w", (df * 2, df, 4, 4), "normal:0.02"),
        ("d.conv2.b", (df * 2,), "zeros"),
        ("d.conv3.w", (df * 4, df * 2, 4, 4), "normal:0.02"),
        ("d.conv3.b", (df * 4,), "zeros"),
        ("d.dense.w", (df * 4 * 4 * 4, 1), "normal:0.02"),
        ("d.dense.b", (1,), "zeros"),
    ]
    return g_spec, d_spec


def _dcgan_g_apply(gf: int):
    def g_apply(p, z, y_onehot, prec: Precision):
        n = 4
        h = dense(z, p["g.dense.w"], p["g.dense.b"], compute_dtype=prec.compute_dtype(0, n))
        h = jax.nn.relu(h).reshape(z.shape[0], gf * 4, 4, 4)
        h = h.astype(prec.act_dtype(1, n))
        h = jax.nn.relu(
            conv2d_transpose(h, p["g.convt1.w"], p["g.convt1.b"], 2, 1, prec.compute_dtype(1, n))
        )
        h = h.astype(prec.act_dtype(2, n))
        h = jax.nn.relu(
            conv2d_transpose(h, p["g.convt2.w"], p["g.convt2.b"], 2, 1, prec.compute_dtype(2, n))
        )
        h = h.astype(prec.act_dtype(3, n))
        h = conv2d_transpose(h, p["g.convt3.w"], p["g.convt3.b"], 2, 1, prec.compute_dtype(3, n))
        return jnp.tanh(h.astype(jnp.float32))

    return g_apply


def _dcgan_d_apply(df: int, sn: bool):
    def d_apply(p, x, y_onehot, prec: Precision):
        n = 4
        norm = spectral_norm if sn else (lambda w: w)
        h = x.astype(prec.act_dtype(0, n))
        h = lrelu(conv2d(h, norm(p["d.conv1.w"]), p["d.conv1.b"], 2, 1, prec.compute_dtype(0, n)))
        h = h.astype(prec.act_dtype(1, n))
        h = lrelu(conv2d(h, norm(p["d.conv2.w"]), p["d.conv2.b"], 2, 1, prec.compute_dtype(1, n)))
        h = h.astype(prec.act_dtype(2, n))
        h = lrelu(conv2d(h, norm(p["d.conv3.w"]), p["d.conv3.b"], 2, 1, prec.compute_dtype(2, n)))
        h = h.astype(prec.act_dtype(3, n)).reshape(x.shape[0], -1)
        logits = dense(h, norm(p["d.dense.w"]) if sn else p["d.dense.w"], p["d.dense.b"],
                       compute_dtype=prec.compute_dtype(3, n))
        return logits[:, 0]

    return d_apply


def dcgan32(gf: int = 32, df: int = 32, z_dim: int = 128) -> ModelDef:
    g_spec, d_spec = _dcgan_specs(gf, df, z_dim)
    return ModelDef(
        name="dcgan32", z_dim=z_dim, img_shape=(3, 32, 32), n_classes=0, loss="bce",
        g_spec=g_spec, d_spec=d_spec,
        g_apply=_dcgan_g_apply(gf), d_apply=_dcgan_d_apply(df, sn=False),
    )


def sngan32(gf: int = 32, df: int = 32, z_dim: int = 128) -> ModelDef:
    g_spec, d_spec = _dcgan_specs(gf, df, z_dim)
    return ModelDef(
        name="sngan32", z_dim=z_dim, img_shape=(3, 32, 32), n_classes=0, loss="hinge",
        g_spec=g_spec, d_spec=d_spec,
        g_apply=_dcgan_g_apply(gf), d_apply=_dcgan_d_apply(df, sn=True),
    )


# ---------------------------------------------------------------------------
# BigGAN-lite 32 — conditional residual GAN with projection discriminator.
# ---------------------------------------------------------------------------

def _biggan_specs(ch: int, z_dim: int, n_classes: int, emb_dim: int) -> Tuple[ParamSpec, ParamSpec]:
    g_spec = [
        ("g.embed.w", (n_classes, emb_dim), "normal:0.02"),
        ("g.dense.w", (z_dim + emb_dim, 4 * 4 * ch * 4), "normal:0.02"),
        ("g.dense.b", (4 * 4 * ch * 4,), "zeros"),
    ]
    # Three up-blocks: 4->8->16->32, channels 4ch -> 2ch -> ch -> ch.
    blocks = [(ch * 4, ch * 2), (ch * 2, ch), (ch, ch)]
    for i, (cin, cout) in enumerate(blocks, start=1):
        g_spec += [
            # FiLM conditioning from the class embedding.
            (f"g.b{i}.film.w", (emb_dim, 2 * cin), "normal:0.02"),
            (f"g.b{i}.film.b", (2 * cin,), "zeros"),
            (f"g.b{i}.conv1.w", (cout, cin, 3, 3), "normal:0.02"),
            (f"g.b{i}.conv1.b", (cout,), "zeros"),
            (f"g.b{i}.conv2.w", (cout, cout, 3, 3), "normal:0.02"),
            (f"g.b{i}.conv2.b", (cout,), "zeros"),
            (f"g.b{i}.skip.w", (cout, cin, 1, 1), "normal:0.02"),
        ]
    g_spec += [
        ("g.out.w", (3, ch, 3, 3), "normal:0.02"),
        ("g.out.b", (3,), "zeros"),
    ]
    d_spec = []
    # Three down-blocks: 32->16->8->4, channels 3 -> ch -> 2ch -> 4ch.
    dblocks = [(3, ch), (ch, ch * 2), (ch * 2, ch * 4)]
    for i, (cin, cout) in enumerate(dblocks, start=1):
        d_spec += [
            (f"d.b{i}.conv1.w", (cout, cin, 3, 3), "normal:0.02"),
            (f"d.b{i}.conv1.b", (cout,), "zeros"),
            (f"d.b{i}.conv2.w", (cout, cout, 3, 3), "normal:0.02"),
            (f"d.b{i}.conv2.b", (cout,), "zeros"),
            (f"d.b{i}.skip.w", (cout, cin, 1, 1), "normal:0.02"),
        ]
    d_spec += [
        ("d.dense.w", (ch * 4, 1), "normal:0.02"),
        ("d.dense.b", (1,), "zeros"),
        # Projection head (Miyato & Koyama 2018), as used by BigGAN.
        ("d.proj.w", (n_classes, ch * 4), "normal:0.02"),
    ]
    return g_spec, d_spec


def _upsample2(x):
    """Nearest-neighbour 2x upsample, NCHW."""
    b, c, h, w = x.shape
    x = x[:, :, :, None, :, None]
    x = jnp.broadcast_to(x, (b, c, h, 2, w, 2))
    return x.reshape(b, c, h * 2, w * 2)


def _avgpool2(x):
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


def _biggan_g_apply(ch: int):
    def g_apply(p, z, y_onehot, prec: Precision):
        n = 5  # dense + 3 blocks + out conv
        emb = y_onehot @ p["g.embed.w"]  # (B, emb)
        h = dense(jnp.concatenate([z, emb], axis=1), p["g.dense.w"], p["g.dense.b"],
                  compute_dtype=prec.compute_dtype(0, n))
        h = h.reshape(z.shape[0], ch * 4, 4, 4)
        for i in (1, 2, 3):
            cdt = prec.compute_dtype(i, n)
            h = h.astype(prec.act_dtype(i, n))
            film = emb @ p[f"g.b{i}.film.w"] + p[f"g.b{i}.film.b"]
            gamma, beta = jnp.split(film, 2, axis=1)
            hc = h * (1.0 + gamma[:, :, None, None]) + beta[:, :, None, None]
            hc = _upsample2(jax.nn.relu(hc))
            hc2 = jax.nn.relu(conv2d(hc, p[f"g.b{i}.conv1.w"], p[f"g.b{i}.conv1.b"], 1, 1, cdt))
            hc2 = conv2d(hc2, p[f"g.b{i}.conv2.w"], p[f"g.b{i}.conv2.b"], 1, 1, cdt)
            skip = conv2d(hc, p[f"g.b{i}.skip.w"], None, 1, 0, cdt)
            h = hc2 + skip
        h = jax.nn.relu(h.astype(jnp.float32))
        out = conv2d(h, p["g.out.w"], p["g.out.b"], 1, 1, prec.compute_dtype(n - 1, n))
        return jnp.tanh(out)

    return g_apply


def _biggan_d_apply(ch: int):
    def d_apply(p, x, y_onehot, prec: Precision):
        n = 4  # 3 blocks + head
        h = x
        for i in (1, 2, 3):
            cdt = prec.compute_dtype(i - 1, n)
            h = h.astype(prec.act_dtype(i - 1, n))
            hc = jax.nn.relu(conv2d(h, spectral_norm(p[f"d.b{i}.conv1.w"]), p[f"d.b{i}.conv1.b"], 1, 1, cdt))
            hc = conv2d(hc, spectral_norm(p[f"d.b{i}.conv2.w"]), p[f"d.b{i}.conv2.b"], 1, 1, cdt)
            skip = conv2d(h, spectral_norm(p[f"d.b{i}.skip.w"]), None, 1, 0, cdt)
            h = _avgpool2(jax.nn.relu(hc + skip))
        feat = h.astype(jnp.float32).sum(axis=(2, 3))  # (B, 4ch) global sum-pool
        logits = dense(feat, spectral_norm(p["d.dense.w"]), p["d.dense.b"],
                       compute_dtype=prec.compute_dtype(n - 1, n))[:, 0]
        proj = jnp.sum((y_onehot @ p["d.proj.w"]) * feat, axis=1)
        return logits + proj

    return d_apply


def biggan32(ch: int = 32, z_dim: int = 120, n_classes: int = 8, emb_dim: int = 32) -> ModelDef:
    g_spec, d_spec = _biggan_specs(ch, z_dim, n_classes, emb_dim)
    return ModelDef(
        name="biggan32", z_dim=z_dim, img_shape=(3, 32, 32), n_classes=n_classes, loss="hinge",
        g_spec=g_spec, d_spec=d_spec,
        g_apply=_biggan_g_apply(ch), d_apply=_biggan_d_apply(ch),
    )


MODELS: Dict[str, Callable[[], ModelDef]] = {
    "dcgan32": dcgan32,
    "sngan32": sngan32,
    "biggan32": biggan32,
}


# ---------------------------------------------------------------------------
# Training step builders — fwd + bwd + optimizer update as ONE jax function.
# ---------------------------------------------------------------------------

def make_d_step(model: ModelDef, opt_name: str, prec: Precision, hp: HParams,
                clip_norm: Optional[float] = None):
    """D update: consumes real AND pre-generated fake images (async img_buff).

    (step, lr, d_params, d_opt_state, real, fake[, y_onehot])
      -> (new_d_params, new_opt_state, d_loss, real_logits, fake_logits)
    """
    d_loss_fn, _ = LOSSES[model.loss]
    _, update_fn, _ = OPTIMIZERS[opt_name]

    def d_step(step, lr, d_params, d_opt, real, fake, y_onehot=None):
        def loss_fn(dp):
            rl = model.d_apply(dp, real, y_onehot, prec)
            fl = model.d_apply(dp, fake, y_onehot, prec)
            return d_loss_fn(rl, fl), (rl, fl)

        (loss, (rl, fl)), grads = jax.value_and_grad(loss_fn, has_aux=True)(d_params)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        new_p, new_s = update_fn(grads, d_opt, d_params, step, hp, lr)
        return new_p, new_s, loss, rl, fl

    return d_step


def make_g_step(model: ModelDef, opt_name: str, prec: Precision, hp: HParams,
                clip_norm: Optional[float] = None):
    """G update against a (possibly stale) snapshot of D params.

    (step, lr, g_params, g_opt_state, d_params_snapshot, z[, y_onehot])
      -> (new_g_params, new_opt_state, g_loss, fake_images)
    """
    _, g_loss_fn = LOSSES[model.loss]
    _, update_fn, _ = OPTIMIZERS[opt_name]

    def g_step(step, lr, g_params, g_opt, d_params, z, y_onehot=None):
        def loss_fn(gp):
            fake = model.g_apply(gp, z, y_onehot, prec)
            fl = model.d_apply(d_params, fake, y_onehot, prec)
            return g_loss_fn(fl), fake

        (loss, fake), grads = jax.value_and_grad(loss_fn, has_aux=True)(g_params)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        new_p, new_s = update_fn(grads, g_opt, g_params, step, hp, lr)
        return new_p, new_s, loss, fake

    return g_step


def make_generate(model: ModelDef, prec: Precision):
    """(g_params, z[, y_onehot]) -> images — eval/serving path."""

    def generate(g_params, z, y_onehot=None):
        return model.g_apply(g_params, z, y_onehot, prec)

    return generate


# ---------------------------------------------------------------------------
# FID-proxy feature extractor: fixed random conv net (substitution for
# Inception-v3, see DESIGN.md §1).  Weights are constants baked into the HLO.
# ---------------------------------------------------------------------------

FID_FEAT_DIM = 64


def make_fid_features(img_shape: Tuple[int, int, int]):
    rng = np.random.RandomState(42)
    w1 = jnp.asarray(rng.normal(0, 0.3, size=(32, img_shape[0], 4, 4)), dtype=jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 0.15, size=(FID_FEAT_DIM, 32, 4, 4)), dtype=jnp.float32)

    def fid_features(images):
        from .kernels.ref import ref_conv2d  # eval-only path: plain lax conv

        h = lrelu(ref_conv2d(images, w1, None, stride=4, padding=0))
        h = lrelu(ref_conv2d(h, w2, None, stride=2, padding=1))
        return h.mean(axis=(2, 3))  # (B, 64)

    return fid_features
