"""Mixed-precision policy (paper §4.3).

Findings the paper reports, encoded as a policy object:

  * activations tolerate bf16; weights and gradients are sensitive → master
    params and the optimizer update stay f32, only *activations* are cast;
  * "the generator and discriminator's last layer are more sensitive to
    precision" and shallow layers are less sensitive than deep ones → the
    first and last layers of each network run f32;
  * Adam ``eps`` must be bumped when running low precision.

The policy is applied per-layer inside the model functions: each layer asks
``act_dtype(layer_idx, n_layers)`` what to compute in.  ``compute_dtype``
selects the MXU input precision inside the Pallas matmul.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Precision:
    """Per-network numeric policy."""

    name: str = "fp32"
    bf16_activations: bool = False
    first_layer_fp32: bool = True
    last_layer_fp32: bool = True

    def act_dtype(self, layer_idx: int, n_layers: int) -> str:
        if not self.bf16_activations:
            return "float32"
        if self.first_layer_fp32 and layer_idx == 0:
            return "float32"
        if self.last_layer_fp32 and layer_idx == n_layers - 1:
            return "float32"
        return "bfloat16"

    def compute_dtype(self, layer_idx: int, n_layers: int) -> str:
        # MXU input precision for the Pallas matmul of this layer.
        return self.act_dtype(layer_idx, n_layers)

    def adam_eps(self, base: float = 1e-8) -> float:
        # Paper: "it is necessary to use a slightly larger eps value" for bf16.
        return 1e-6 if self.bf16_activations else base


FP32 = Precision("fp32", bf16_activations=False)
BF16 = Precision("bf16", bf16_activations=True)

PRECISIONS = {"fp32": FP32, "bf16": BF16}
