"""AOT compiler: lower every (model, optimizer, precision) training step to
HLO **text** + a JSON manifest the rust runtime loads.

This is the single point where Python runs — ``make artifacts`` — and it runs
once.  After that the rust binary is self-contained: it parses
``artifacts/manifest.json``, loads each ``*.hlo.txt`` through
``HloModuleProto::from_text_file``, compiles on the PJRT CPU client, and owns
the whole training loop.

HLO *text*, never ``.serialize()``: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Artifact interface convention (what the manifest encodes):

  * every argument and result is a f32 tensor (casts live inside the graph),
  * arguments are FLAT and ordered; each manifest entry carries a ``role``:
      - ``step``            — 1-based step counter, f32 scalar
      - ``param:<name>``    — network parameter
      - ``slot<k>:<name>``  — optimizer state slot k for parameter <name>
      - ``in:<name>``       — data input (real, fake, z, y_onehot, images)
      - ``out:<name>``      — extra outputs (loss, logits, images, features)
  * results are a flat tuple: updated params (spec order), updated slots
    (slot-major), then the extra outputs.

The rust ``runtime::artifact`` module is the mirror image of this file.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (MODELS, ModelDef, make_d_step, make_g_step, make_generate,
                    make_fid_features, FID_FEAT_DIM)
from .optimizers import OPTIMIZERS, HParams
from .precision import PRECISIONS, Precision

DEFAULT_BATCH = 32

# Export sets: which (optimizer, precision) step variants each backbone gets.
# dcgan32 carries the full optimizer zoo (Fig. 6 sweeps); the heavier
# backbones carry the pair the paper's asymmetric policy actually uses.
EXPORT_SETS = {
    "dcgan32": {
        "opts": ["adam", "adabelief", "radam", "lookahead", "lars"],
        "precs": ["fp32", "bf16"],
        "bf16_opts": ["adam", "adabelief"],
    },
    "sngan32": {"opts": ["adam", "adabelief"], "precs": ["fp32"], "bf16_opts": []},
    "biggan32": {"opts": ["adam", "adabelief"], "precs": ["fp32"], "bf16_opts": []},
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides payloads as
    # "{...}", which the rust-side text parser would silently read back as
    # zeros — the FID feature net's baked weights live in constants.
    text = comp.as_hlo_text(True)
    assert "constant({...})" not in text, "elided constant in HLO text"
    return text


def _sds(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _spec_entries(prefix: str, spec) -> List[dict]:
    return [{"role": f"{prefix}:{name}", "shape": list(shape), "dtype": "f32"}
            for name, shape, _ in spec]


def _slot_entries(spec, n_slots: int) -> List[dict]:
    out = []
    for k in range(n_slots):
        out += _spec_entries(f"slot{k}", spec)
    return out


def _hp_for(model: ModelDef, prec: Precision) -> HParams:
    # GAN-customary betas: 0.5 for BCE/DCGAN, 0.0 for hinge (BigGAN/SNGAN).
    b1 = 0.5 if model.loss == "bce" else 0.0
    return HParams(lr=2e-4, b1=b1, eps=prec.adam_eps())


class Exporter:
    def __init__(self, out_dir: str, batch: int):
        self.out_dir = out_dir
        self.batch = batch
        self.manifest = {"version": 1, "batch": batch, "models": {}}
        os.makedirs(out_dir, exist_ok=True)

    def _write(self, name: str, lowered, inputs: List[dict], outputs: List[dict]) -> dict:
        text = to_hlo_text(lowered)
        # Arity self-check: the ENTRY computation must keep every manifest
        # input (XLA prunes dead parameters, which would desync the rust
        # plumbing).  Count parameters only inside the ENTRY computation —
        # fusion/reduction subcomputations have their own.
        entry = text[text.index("ENTRY "):]
        entry = entry[: entry.index("\n}") + 1] if "\n}" in entry else entry
        n_hlo_params = entry.count("parameter(")
        if n_hlo_params != len(inputs):
            raise RuntimeError(
                f"{name}: ENTRY has {n_hlo_params} parameters, manifest expects "
                f"{len(inputs)} — a dead input was pruned")
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        print(f"  wrote {fname}  ({len(text) // 1024} KiB, sha {digest})")
        return {"file": fname, "inputs": inputs, "outputs": outputs, "sha256_16": digest}

    # ------------------------------------------------------------------
    def export_model(self, model: ModelDef):
        cfg = EXPORT_SETS[model.name]
        b = self.batch
        c, h, w = model.img_shape
        img_sds = _sds((b, c, h, w))
        z_sds = _sds((b, model.z_dim))
        y_sds = _sds((b, model.n_classes)) if model.conditional else None

        mrec = {
            "z_dim": model.z_dim,
            "img_shape": list(model.img_shape),
            "n_classes": model.n_classes,
            "loss": model.loss,
            "batch": b,
            "params_g": [{"name": n, "shape": list(s), "init": i} for n, s, i in model.g_spec],
            "params_d": [{"name": n, "shape": list(s), "init": i} for n, s, i in model.d_spec],
            "optimizers": {},
            "artifacts": {},
            "fid_feat_dim": FID_FEAT_DIM,
        }

        for opt in cfg["opts"]:
            _, _, n_slots = OPTIMIZERS[opt]
            # Slot init rule: lookahead slot 2 starts as a copy of params.
            slot_init = ["zeros"] * n_slots
            if opt == "lookahead":
                slot_init[2] = "copy_params"
            mrec["optimizers"][opt] = {"n_slots": n_slots, "slot_init": slot_init}

        for prec_name in cfg["precs"]:
            prec = PRECISIONS[prec_name]
            hp = _hp_for(model, prec)
            opts = cfg["opts"] if prec_name == "fp32" else cfg["bf16_opts"]
            for opt in opts:
                self._export_d_step(model, mrec, opt, prec, hp, img_sds, y_sds)
                self._export_g_step(model, mrec, opt, prec, hp, z_sds, y_sds)

        self._export_generate(model, mrec, PRECISIONS["fp32"], z_sds, y_sds)
        self._export_fid(model, mrec, img_sds)
        self.manifest["models"][model.name] = mrec

    # ------------------------------------------------------------------
    def _export_d_step(self, model, mrec, opt, prec, hp, img_sds, y_sds):
        name = f"{model.name}_d_step_{opt}_{prec.name}"
        print(f"lowering {name} ...")
        _, _, n_slots = OPTIMIZERS[opt]
        d_step = make_d_step(model, opt, prec, hp)
        spec = model.d_spec
        np_ = len(spec)

        def flat(*args):
            i = 0
            step = args[i]; i += 1
            # Tie lr to step so neither scalar is dead (optimizers like LARS
            # ignore `step`; XLA would prune the parameter and break the
            # manifest arity).
            lr = args[i] + 0.0 * step; i += 1
            params = {spec[j][0]: args[i + j] for j in range(np_)}; i += np_
            slots = tuple({spec[j][0]: args[i + k * np_ + j] for j in range(np_)}
                          for k in range(n_slots)); i += n_slots * np_
            real = args[i]; fake = args[i + 1]; i += 2
            y = args[i] if y_sds is not None else None
            new_p, new_s, loss, rl, fl = d_step(step, lr, params, slots, real, fake, y)
            out = tuple(new_p[n] for n, _, _ in spec)
            for k in range(n_slots):
                out += tuple(new_s[k][n] for n, _, _ in spec)
            return out + (loss, rl, fl)

        inputs = [{"role": "step", "shape": [], "dtype": "f32"},
                  {"role": "lr", "shape": [], "dtype": "f32"}]
        inputs += _spec_entries("param", spec)
        inputs += _slot_entries(spec, n_slots)
        inputs += [{"role": "in:real", "shape": list(img_sds.shape), "dtype": "f32"},
                   {"role": "in:fake", "shape": list(img_sds.shape), "dtype": "f32"}]
        if y_sds is not None:
            inputs += [{"role": "in:y", "shape": list(y_sds.shape), "dtype": "f32"}]
        outputs = _spec_entries("param", spec) + _slot_entries(spec, n_slots)
        outputs += [{"role": "out:loss", "shape": [], "dtype": "f32"},
                    {"role": "out:real_logits", "shape": [img_sds.shape[0]], "dtype": "f32"},
                    {"role": "out:fake_logits", "shape": [img_sds.shape[0]], "dtype": "f32"}]

        args = [_sds(e["shape"]) for e in inputs]
        lowered = jax.jit(flat).lower(*args)
        mrec["artifacts"][f"d_step_{opt}_{prec.name}"] = self._write(name, lowered, inputs, outputs)

    def _export_g_step(self, model, mrec, opt, prec, hp, z_sds, y_sds):
        name = f"{model.name}_g_step_{opt}_{prec.name}"
        print(f"lowering {name} ...")
        _, _, n_slots = OPTIMIZERS[opt]
        g_step = make_g_step(model, opt, prec, hp)
        gspec, dspec = model.g_spec, model.d_spec
        ng, nd = len(gspec), len(dspec)

        def flat(*args):
            i = 0
            step = args[i]; i += 1
            lr = args[i] + 0.0 * step; i += 1  # keep both scalars alive
            gp = {gspec[j][0]: args[i + j] for j in range(ng)}; i += ng
            slots = tuple({gspec[j][0]: args[i + k * ng + j] for j in range(ng)}
                          for k in range(n_slots)); i += n_slots * ng
            dp = {dspec[j][0]: args[i + j] for j in range(nd)}; i += nd
            z = args[i]; i += 1
            y = args[i] if y_sds is not None else None
            new_p, new_s, loss, fake = g_step(step, lr, gp, slots, dp, z, y)
            out = tuple(new_p[n] for n, _, _ in gspec)
            for k in range(n_slots):
                out += tuple(new_s[k][n] for n, _, _ in gspec)
            return out + (loss, fake)

        b = z_sds.shape[0]
        c, h, w = model.img_shape
        inputs = [{"role": "step", "shape": [], "dtype": "f32"},
                  {"role": "lr", "shape": [], "dtype": "f32"}]
        inputs += _spec_entries("param", gspec)
        inputs += _slot_entries(gspec, n_slots)
        inputs += _spec_entries("dparam", dspec)
        inputs += [{"role": "in:z", "shape": list(z_sds.shape), "dtype": "f32"}]
        if y_sds is not None:
            inputs += [{"role": "in:y", "shape": list(y_sds.shape), "dtype": "f32"}]
        outputs = _spec_entries("param", gspec) + _slot_entries(gspec, n_slots)
        outputs += [{"role": "out:loss", "shape": [], "dtype": "f32"},
                    {"role": "out:fake", "shape": [b, c, h, w], "dtype": "f32"}]

        args = [_sds(e["shape"]) for e in inputs]
        lowered = jax.jit(flat).lower(*args)
        mrec["artifacts"][f"g_step_{opt}_{prec.name}"] = self._write(name, lowered, inputs, outputs)

    def _export_generate(self, model, mrec, prec, z_sds, y_sds):
        name = f"{model.name}_generate_{prec.name}"
        print(f"lowering {name} ...")
        gen = make_generate(model, prec)
        gspec = model.g_spec
        ng = len(gspec)

        def flat(*args):
            gp = {gspec[j][0]: args[j] for j in range(ng)}
            z = args[ng]
            y = args[ng + 1] if y_sds is not None else None
            return (gen(gp, z, y),)

        b = z_sds.shape[0]
        c, h, w = model.img_shape
        inputs = _spec_entries("param", gspec)
        inputs += [{"role": "in:z", "shape": list(z_sds.shape), "dtype": "f32"}]
        if y_sds is not None:
            inputs += [{"role": "in:y", "shape": list(y_sds.shape), "dtype": "f32"}]
        outputs = [{"role": "out:images", "shape": [b, c, h, w], "dtype": "f32"}]
        args = [_sds(e["shape"]) for e in inputs]
        lowered = jax.jit(flat).lower(*args)
        mrec["artifacts"][f"generate_{prec.name}"] = self._write(name, lowered, inputs, outputs)

    def _export_fid(self, model, mrec, img_sds):
        name = f"{model.name}_fid_features"
        print(f"lowering {name} ...")
        feats = make_fid_features(model.img_shape)

        def flat(images):
            return (feats(images),)

        b = img_sds.shape[0]
        inputs = [{"role": "in:images", "shape": list(img_sds.shape), "dtype": "f32"}]
        outputs = [{"role": "out:features", "shape": [b, FID_FEAT_DIM], "dtype": "f32"}]
        lowered = jax.jit(flat).lower(_sds(inputs[0]["shape"]))
        mrec["artifacts"]["fid_features"] = self._write(name, lowered, inputs, outputs)

    # ------------------------------------------------------------------
    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {path} ({len(self.manifest['models'])} models)")


def main(argv=None):
    ap = argparse.ArgumentParser(description="ParaGAN AOT exporter (L2 -> HLO text)")
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--models", default="dcgan32,sngan32,biggan32",
                    help="comma-separated subset of models to export")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    args = ap.parse_args(argv)

    ex = Exporter(args.out, args.batch)
    for mname in args.models.split(","):
        mname = mname.strip()
        if not mname:
            continue
        print(f"== exporting {mname} ==")
        ex.export_model(MODELS[mname]())
    ex.finish()


if __name__ == "__main__":
    main()
