"""L1 Pallas kernel: hardware-aware layout-transformed tiled matmul.

This is ParaGAN's "hardware-aware layout transformation" (paper §4.2) pushed
down to the kernel level.  TPU vector memory is tiled (sublane=8, lane=128):
an operand whose trailing dims are not multiples of (8, 128) is padded by the
hardware anyway, silently wasting MXU cycles.  ParaGAN makes the padding
explicit and *plans* it:

  * operands are padded up-front to (8, 128) multiples (`pad2d`),
  * the matmul runs as a Pallas grid over (M/bm, N/bn, K/bk) VMEM-resident
    blocks chosen by `plan_matmul` to fit a VMEM budget,
  * the MXU is modelled by casting blocks to ``compute_dtype`` (bf16 on real
    TPU) and accumulating in f32 (``preferred_element_type``),
  * the result is sliced back to the logical shape.

The kernel is wrapped in a ``jax.custom_vjp`` so the backward pass is *also*
three Pallas matmuls (dx = g·Wᵀ, dW = xᵀ·g) — the whole GAN fwd+bwd lowers to
layout-aware kernels.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).  Real-TPU performance is
estimated from the plan's VMEM footprint and MXU occupancy (`vmem_bytes`,
`mxu_occupancy`) — never from interpret-mode wallclock.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU vector-register tiling (paper §3.3: "multiple of 128 on the lane
# dimension and 8 on the sublane dimension").
SUBLANE = 8
LANE = 128

# Per-core VMEM budget used by the block planner (TPUv3 has 16 MiB/core; we
# plan against half to leave room for double-buffering).
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# MXU systolic array is 128x128.
MXU_DIM = 128


def round_up(n: int, m: int) -> int:
    """Round ``n`` up to the next multiple of ``m``."""
    return ((n + m - 1) // m) * m


def pad2d(x: jnp.ndarray, row_tile: int = SUBLANE, col_tile: int = LANE):
    """Zero-pad the trailing 2 dims of ``x`` to (row_tile, col_tile) multiples.

    Returns ``(padded, (orig_rows, orig_cols))``.
    """
    r, c = x.shape[-2], x.shape[-1]
    rp, cp = round_up(r, row_tile), round_up(c, col_tile)
    if (rp, cp) == (r, c):
        return x, (r, c)
    pad = [(0, 0)] * (x.ndim - 2) + [(0, rp - r), (0, cp - c)]
    return jnp.pad(x, pad), (r, c)


def _divisor_block(dim: int, pref: int, tile: int) -> int:
    """Largest multiple of ``tile`` that divides ``dim`` and is <= ``pref``.

    ``dim`` must itself be a multiple of ``tile`` (post-padding), so ``tile``
    is always a valid fallback.
    """
    assert dim % tile == 0, (dim, tile)
    best = tile
    b = tile
    while b <= min(dim, pref):
        if dim % b == 0:
            best = b
        b += tile
    return best


@dataclass(frozen=True)
class MatmulPlan:
    """Block plan for a padded (M, K) x (K, N) matmul."""

    m: int
    k: int
    n: int  # logical dims
    mp: int
    kp: int
    np_: int  # padded dims
    bm: int
    bk: int
    bn: int  # block dims
    compute_dtype: str = "float32"

    @property
    def grid(self):
        return (self.mp // self.bm, self.np_ // self.bn, self.kp // self.bk)

    def vmem_bytes(self) -> int:
        """VMEM residency of one grid step: x-block + w-block + out-block.

        Blocks are held at compute precision except the f32 accumulator.
        """
        esz = 2 if self.compute_dtype == "bfloat16" else 4
        return self.bm * self.bk * esz + self.bk * self.bn * esz + self.bm * self.bn * 4

    def mxu_occupancy(self) -> float:
        """Fraction of MXU work that is non-padding: real FLOPs / padded FLOPs."""
        real = 2.0 * self.m * self.k * self.n
        padded = 2.0 * self.mp * self.kp * self.np_
        return real / padded

    def padding_waste(self) -> float:
        return 1.0 - self.mxu_occupancy()


def plan_matmul(m: int, k: int, n: int, compute_dtype: str = "float32") -> MatmulPlan:
    """Choose padded dims and VMEM-budgeted block sizes for an (m,k)x(k,n) matmul."""
    mp = round_up(m, SUBLANE)
    kp = round_up(k, LANE)
    np_ = round_up(n, LANE)
    # Prefer tall M-blocks (fewer grid trips over the batch*spatial rows —
    # §Perf iterations 1+3: 256 -> 1024 -> 2048 cut interpret-mode grid trips 8x),
    # then shrink K-block until the plan fits VMEM.
    bm = _divisor_block(mp, 2048, SUBLANE)
    bn = _divisor_block(np_, 256, LANE)
    pref_k = 2048
    while True:
        bk = _divisor_block(kp, pref_k, LANE)
        plan = MatmulPlan(m, k, n, mp, kp, np_, bm, bk, bn, compute_dtype)
        if plan.vmem_bytes() <= VMEM_BUDGET_BYTES or bk == LANE:
            return plan
        pref_k = bk - LANE


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int, compute_dtype):
    """Grid point (i, j, kidx): o[i,j] += x[i,kidx] @ w[kidx,j].

    The output block's index_map ignores the k axis, so the same VMEM-resident
    o-block accumulates across the innermost grid dimension (standard Pallas
    reduction pattern); f32 accumulation models the MXU datapath.
    """
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(compute_dtype)
    wb = w_ref[...].astype(compute_dtype)
    o_ref[...] += jnp.dot(xb, wb, preferred_element_type=jnp.float32)


def _matmul_padded(xp: jnp.ndarray, wp: jnp.ndarray, plan: MatmulPlan) -> jnp.ndarray:
    """Run the Pallas kernel on pre-padded operands; returns padded (MP, NP) f32."""
    cdt = jnp.dtype(plan.compute_dtype)
    gm, gn, gk = plan.grid
    kernel = functools.partial(_matmul_kernel, nk=gk, compute_dtype=cdt)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((plan.bm, plan.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((plan.bk, plan.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((plan.bm, plan.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((plan.mp, plan.np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xp, wp)


def _layout_matmul_impl(x: jnp.ndarray, w: jnp.ndarray, compute_dtype: str) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    # §Perf iteration 2 (orientation selection — swap operand roles when the
    # output is skinny, e.g. the RGB head's n=3 padding to 128) was tried
    # and REVERTED: it reduces padded FLOPs 16x on that layer but interpret
    # mode is grid-iteration-bound, and the transposed plan has 4x more grid
    # trips (g_step 7.4s -> 13.2s measured).  On real TPU hardware the
    # padded-FLOP metric governs; the planner note in EXPERIMENTS.md §Perf
    # records both numbers.
    plan = plan_matmul(m, k, n, compute_dtype)
    xp, _ = pad2d(x.astype(jnp.float32), SUBLANE, LANE)
    # w is padded K->sublane-of-x's-lane: K pads to LANE to match x's cols.
    wp, _ = pad2d(w.astype(jnp.float32), LANE, LANE)
    # pad2d leaves K at round_up(k, LANE) for both operands.
    out = _matmul_padded(xp, wp, plan)
    return out[:m, :n]


def make_layout_matmul(compute_dtype: str = "float32"):
    """Build a differentiable layout-aware matmul with the given MXU precision.

    The returned ``fn(x, w) -> x @ w`` has a custom VJP whose backward pass is
    two more layout-aware Pallas matmuls.
    """

    @jax.custom_vjp
    def layout_matmul(x, w):
        return _layout_matmul_impl(x, w, compute_dtype)

    def fwd(x, w):
        return _layout_matmul_impl(x, w, compute_dtype), (x, w)

    def bwd(res, g):
        x, w = res
        g = g.astype(jnp.float32)
        dx = _layout_matmul_impl(g, w.T, compute_dtype)
        dw = _layout_matmul_impl(x.T, g, compute_dtype)
        return dx.astype(x.dtype), dw.astype(w.dtype)

    layout_matmul.defvjp(fwd, bwd)
    return layout_matmul


# Default instances.
layout_matmul = make_layout_matmul("float32")
layout_matmul_bf16 = make_layout_matmul("bfloat16")


def opportunistic_batch_matmul(xs, w, compute_dtype: str = "float32"):
    """Paper §4.2: "if two input matrices are to multiply the same weight, we
    can concatenate the two input matrices before the matrix multiplication".

    Concatenates ``xs`` along rows, runs ONE layout matmul (one kernel launch,
    better M-padding amortization), and splits the result back.
    """
    mm = layout_matmul_bf16 if compute_dtype == "bfloat16" else layout_matmul
    rows = [x.shape[0] for x in xs]
    out = mm(jnp.concatenate(xs, axis=0), w)
    splits = []
    off = 0
    for r in rows:
        splits.append(out[off : off + r])
        off += r
    return splits
