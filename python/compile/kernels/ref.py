"""Pure-jnp/lax oracles for the Pallas kernels (pytest compares against these).

Everything here is the *reference semantics*: plain XLA ops with no layout
planning, no tiling, no precision games.  The kernels in this package must be
``allclose`` to these for every shape/dtype the tests sweep.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ref_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference (M,K)x(K,N) matmul in f32."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def ref_conv2d(x, w, b=None, stride: int = 1, padding: int = 0):
    """Reference NCHW/OIHW conv with symmetric padding."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out


def ref_batchnorm(x, gamma, beta, mean=None, var=None, eps: float = 1e-5):
    """Reference NCHW BatchNorm.

    Train mode (``mean``/``var`` None): per-channel batch statistics over
    the (N, H, W) axes with *biased* variance.  Inference mode: normalize
    with the given fixed statistics.  Matches the Rust
    ``ref_conv::bn_stats``/``bn_apply`` pair.
    """
    x = x.astype(jnp.float32)
    if mean is None:
        mean = x.mean(axis=(0, 2, 3))
        var = x.var(axis=(0, 2, 3))
    mean = jnp.asarray(mean, jnp.float32).reshape(1, -1, 1, 1)
    var = jnp.asarray(var, jnp.float32).reshape(1, -1, 1, 1)
    xhat = (x - mean) / jnp.sqrt(var + eps)
    return xhat * gamma.reshape(1, -1, 1, 1) + beta.reshape(1, -1, 1, 1)


def ref_upsample_nearest(x, factor: int = 2):
    """Reference NCHW nearest-neighbour upsampling."""
    return jnp.repeat(jnp.repeat(x, factor, axis=2), factor, axis=3)


def ref_conv2d_transpose(x, w, b=None, stride: int = 2, padding: int = 1):
    """Reference fractionally-strided (transposed) conv.

    ``w`` is OIHW with O = input channels of ``x`` (gradient-of-conv
    convention): equivalent to conv with lhs_dilation=stride, padding
    k-1-p, spatially-flipped kernel, and I/O channel axes swapped.
    """
    kh, kw = w.shape[2], w.shape[3]
    wt = jnp.flip(w, axis=(2, 3)).swapaxes(0, 1)  # -> (I_out, O_in, kh, kw)
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        wt.astype(jnp.float32),
        window_strides=(1, 1),
        padding=[(kh - 1 - padding, kh - 1 - padding), (kw - 1 - padding, kw - 1 - padding)],
        lhs_dilation=(stride, stride),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out
