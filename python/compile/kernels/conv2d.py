"""L1: conv2d / conv2d_transpose built on the layout-aware Pallas matmul.

GAN compute is conv-dominated (paper Fig. 4), and on TPU a convolution is an
im2col + MXU matmul.  We express that directly: patch extraction is a cheap,
differentiable data-movement op (``conv_general_dilated_patches``), and ALL
FLOPs flow through ``layout_matmul`` — so the paper's layout transformation
applies to every conv in the model, forward and backward.

Transposed conv (the generator's upsampling op) is implemented as
zero-insertion (lhs dilation) + a stride-1 conv with the spatially-flipped,
channel-swapped kernel — the classic fractionally-strided-conv identity — so
it reuses the same Pallas matmul.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .layout_matmul import layout_matmul, layout_matmul_bf16


def _mm(compute_dtype: str):
    return layout_matmul_bf16 if compute_dtype == "bfloat16" else layout_matmul


def conv2d(x, w, b=None, stride: int = 1, padding: int = 0, compute_dtype: str = "float32"):
    """NCHW conv, OIHW weights, symmetric padding; FLOPs via Pallas matmul.

    x: (B, C, H, W); w: (O, I, kh, kw) -> (B, O, OH, OW) in f32.
    """
    bsz, cin, h, wdim = x.shape
    cout, cin2, kh, kw = w.shape
    assert cin == cin2, (x.shape, w.shape)
    # (B, C*kh*kw, OH, OW); feature dim ordered C-major then kh, kw — matches
    # w.reshape(O, I*kh*kw) below.
    patches = lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    oh, ow = patches.shape[2], patches.shape[3]
    cols = patches.transpose(0, 2, 3, 1).reshape(bsz * oh * ow, cin * kh * kw)
    wcols = w.astype(jnp.float32).reshape(cout, cin * kh * kw).T
    out = _mm(compute_dtype)(cols, wcols)  # (B*OH*OW, O)
    out = out.reshape(bsz, oh, ow, cout).transpose(0, 3, 1, 2)
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out


def conv2d_transpose(x, w, b=None, stride: int = 2, padding: int = 1, compute_dtype: str = "float32"):
    """Fractionally-strided conv: OIHW ``w`` with O = C_in(x), I = C_out.

    Output spatial size: (H-1)*stride - 2*padding + k.
    """
    cin, cout, kh, kw = w.shape[0], w.shape[1], w.shape[2], w.shape[3]
    assert x.shape[1] == cin, (x.shape, w.shape)
    bsz, _, h, wdim = x.shape
    if stride > 1:
        up_h, up_w = (h - 1) * stride + 1, (wdim - 1) * stride + 1
        up = jnp.zeros((bsz, cin, up_h, up_w), dtype=x.dtype)
        up = up.at[:, :, ::stride, ::stride].set(x)
    else:
        up = x
    # Flip spatially, swap channel axes: (I=cout, O=cin) -> OIHW for conv2d.
    wt = jnp.flip(w, axis=(2, 3)).swapaxes(0, 1)  # (cout, cin, kh, kw)
    return conv2d(up, wt, b, stride=1, padding=kh - 1 - padding, compute_dtype=compute_dtype)


def dense(x, w, b=None, compute_dtype: str = "float32"):
    """(B, F) x (F, O) dense layer through the Pallas matmul."""
    out = _mm(compute_dtype)(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.reshape(1, -1)
    return out
