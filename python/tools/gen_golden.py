"""Generate golden outputs for the Rust RefCpuBackend parity test.

The inputs are produced by a 64-bit LCG that both this script and
``rust/tests/backend_parity.rs`` implement bit-for-bit, so the two sides
agree on the exact f32 input tensors without sharing binary files.  The
outputs are computed by the *reference* kernels in
``python/compile/kernels/ref.py`` — the same oracles the Pallas kernels are
tested against — which makes this file the cross-language contract: Pallas
kernels, XLA, and the Rust reference backend must all match these numbers.

Run from ``python/``:

    python -m tools.gen_golden          # rewrites rust/tests/golden/ref_kernels.json
"""

from __future__ import annotations

import json
import os

import numpy as np

MASK = (1 << 64) - 1
LCG_MUL = 6364136223846793005
LCG_INC = 1442695040888963407


class Lcg:
    """Deterministic f32 stream in [-1, 1); mirrored in Rust."""

    def __init__(self, seed: int):
        self.s = seed & MASK

    def next_f32(self) -> np.float32:
        self.s = (self.s * LCG_MUL + LCG_INC) & MASK
        return np.float32(((self.s >> 40) / float(1 << 24)) * 2.0 - 1.0)

    def fill(self, n: int) -> np.ndarray:
        return np.array([self.next_f32() for _ in range(n)], dtype=np.float32)


# (seed, M, K, N) matmul cases — includes skinny/fat and vector shapes.
MATMUL_CASES = [(1, 5, 7, 3), (2, 8, 16, 4), (3, 1, 32, 1), (4, 16, 8, 8)]

# (seed, B, Cin, IH, IW, Cout, K, stride, pad) — dcgan-ish 4x4/s2 shapes,
# a 3x3/s1 'same' conv, and a rectangular input.  Draw order: x, w, b.
CONV2D_CASES = [
    (11, 2, 3, 8, 8, 4, 4, 2, 1),
    (12, 1, 2, 5, 7, 3, 3, 1, 1),
    (13, 2, 4, 4, 4, 2, 3, 2, 1),
]

# (seed, B, Cin, IH, IW, Cout, K, stride, pad) transposed-conv cases; the
# weight is drawn as (Cin, Cout, K, K) — O = input channels (ref.py
# convention).  Draw order: x, w, b.
CONVT2D_CASES = [
    (21, 2, 4, 4, 4, 3, 4, 2, 1),
    (22, 1, 2, 3, 3, 2, 4, 2, 1),
    (23, 2, 3, 2, 2, 4, 3, 1, 1),
]

# (seed, B, C, H, W, mode) batchnorm cases; mode "train" uses batch stats,
# "inference" draws fixed stats too (var = |draw| + 0.5, mirrored in Rust).
# Draw order: x, gamma, beta[, mean, var_raw].
BATCHNORM_CASES = [
    (31, 4, 3, 4, 4, "train"),
    (32, 2, 2, 3, 5, "train"),
    (33, 2, 3, 4, 4, "inference"),
]

# (seed, B, C, H, W, factor) nearest-upsample cases.  Draw order: x.
UPSAMPLE_CASES = [(41, 2, 3, 3, 3, 2), (42, 1, 2, 2, 4, 3)]


def golden():
    from compile.kernels.ref import (
        ref_batchnorm,
        ref_conv2d,
        ref_conv2d_transpose,
        ref_matmul,
        ref_upsample_nearest,
    )

    def emit(case, y):
        case["y"] = [float(v) for v in np.asarray(y, dtype=np.float32).reshape(-1)]
        return case

    matmul = []
    for seed, m, k, n in MATMUL_CASES:
        lcg = Lcg(seed)
        x = lcg.fill(m * k).reshape(m, k)
        w = lcg.fill(k * n).reshape(k, n)
        matmul.append(emit({"seed": seed, "m": m, "k": k, "n": n}, ref_matmul(x, w)))

    conv2d = []
    for seed, b, cin, ih, iw, cout, k, stride, pad in CONV2D_CASES:
        lcg = Lcg(seed)
        x = lcg.fill(b * cin * ih * iw).reshape(b, cin, ih, iw)
        w = lcg.fill(cout * cin * k * k).reshape(cout, cin, k, k)
        bias = lcg.fill(cout)
        y = ref_conv2d(x, w, bias, stride=stride, padding=pad)
        conv2d.append(
            emit(
                {"seed": seed, "b": b, "cin": cin, "ih": ih, "iw": iw,
                 "cout": cout, "k": k, "stride": stride, "pad": pad},
                y,
            )
        )

    convt2d = []
    for seed, b, cin, ih, iw, cout, k, stride, pad in CONVT2D_CASES:
        lcg = Lcg(seed)
        x = lcg.fill(b * cin * ih * iw).reshape(b, cin, ih, iw)
        w = lcg.fill(cin * cout * k * k).reshape(cin, cout, k, k)
        bias = lcg.fill(cout)
        y = ref_conv2d_transpose(x, w, bias, stride=stride, padding=pad)
        convt2d.append(
            emit(
                {"seed": seed, "b": b, "cin": cin, "ih": ih, "iw": iw,
                 "cout": cout, "k": k, "stride": stride, "pad": pad},
                y,
            )
        )

    batchnorm = []
    for seed, b, c, h, w, mode in BATCHNORM_CASES:
        lcg = Lcg(seed)
        x = lcg.fill(b * c * h * w).reshape(b, c, h, w)
        gamma = lcg.fill(c)
        beta = lcg.fill(c)
        if mode == "inference":
            mean = lcg.fill(c)
            var = np.abs(lcg.fill(c)) + np.float32(0.5)
            y = ref_batchnorm(x, gamma, beta, mean=mean, var=var)
        else:
            y = ref_batchnorm(x, gamma, beta)
        batchnorm.append(
            emit({"seed": seed, "b": b, "c": c, "h": h, "w": w, "mode": mode}, y)
        )

    upsample = []
    for seed, b, c, h, w, factor in UPSAMPLE_CASES:
        lcg = Lcg(seed)
        x = lcg.fill(b * c * h * w).reshape(b, c, h, w)
        y = ref_upsample_nearest(x, factor)
        upsample.append(
            emit({"seed": seed, "b": b, "c": c, "h": h, "w": w, "factor": factor}, y)
        )

    return {
        "format": "paragan-golden",
        "version": 2,
        "matmul": matmul,
        "conv2d": conv2d,
        "conv2d_transpose": convt2d,
        "batchnorm": batchnorm,
        "upsample": upsample,
    }


def main():
    out = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", "ref_kernels.json")
    out = os.path.normpath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(golden(), f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
