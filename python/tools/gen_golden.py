"""Generate golden outputs for the Rust RefCpuBackend parity test.

The inputs are produced by a 64-bit LCG that both this script and
``rust/tests/backend_parity.rs`` implement bit-for-bit, so the two sides
agree on the exact f32 input tensors without sharing binary files.  The
outputs are computed by the *reference* kernels in
``python/compile/kernels/ref.py`` — the same oracles the Pallas kernels are
tested against — which makes this file the cross-language contract: Pallas
kernels, XLA, and the Rust reference backend must all match these numbers.

Run from ``python/``:

    python -m tools.gen_golden          # rewrites rust/tests/golden/ref_kernels.json
"""

from __future__ import annotations

import json
import os

import numpy as np

MASK = (1 << 64) - 1
LCG_MUL = 6364136223846793005
LCG_INC = 1442695040888963407


class Lcg:
    """Deterministic f32 stream in [-1, 1); mirrored in Rust."""

    def __init__(self, seed: int):
        self.s = seed & MASK

    def next_f32(self) -> np.float32:
        self.s = (self.s * LCG_MUL + LCG_INC) & MASK
        return np.float32(((self.s >> 40) / float(1 << 24)) * 2.0 - 1.0)

    def fill(self, n: int) -> np.ndarray:
        return np.array([self.next_f32() for _ in range(n)], dtype=np.float32)


# (seed, M, K, N) matmul cases — includes skinny/fat and vector shapes.
MATMUL_CASES = [(1, 5, 7, 3), (2, 8, 16, 4), (3, 1, 32, 1), (4, 16, 8, 8)]


def golden():
    from compile.kernels.ref import ref_matmul

    cases = []
    for seed, m, k, n in MATMUL_CASES:
        lcg = Lcg(seed)
        x = lcg.fill(m * k).reshape(m, k)
        w = lcg.fill(k * n).reshape(k, n)
        y = np.asarray(ref_matmul(x, w), dtype=np.float32)
        cases.append(
            {
                "seed": seed,
                "m": m,
                "k": k,
                "n": n,
                "y": [float(v) for v in y.reshape(-1)],
            }
        )
    return {"format": "paragan-golden", "version": 1, "matmul": cases}


def main():
    out = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", "ref_kernels.json")
    out = os.path.normpath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(golden(), f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
